//! `cargo bench --bench ablations` — the design-choice ablations DESIGN.md
//! calls out: (a) graph vs VM with host boundaries vs VM with device
//! chaining (isolating the staging share of the executor gap); (b) VM on
//! fp32 (executor penalty exists without quantization); (c) memory-planner
//! arena vs unshared allocation; (d) fusion group counts.

use tvmq::bench::{ablations, memplan_ablation, BenchCtx, BenchOpts};
use tvmq::graph::passes::FusionPass;
use tvmq::graph::build_resnet_ir;
use tvmq::metrics::Table;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts {
        epochs: std::env::var("TVMQ_BENCH_EPOCHS").ok().and_then(|v| v.parse().ok()).unwrap_or(110),
        warmup: 10,
    };
    let ctx = BenchCtx::new(&tvmq::default_artifacts_dir(), opts)?;
    ablations(&ctx)?.print();
    memplan_ablation(&ctx)?.print();

    // Fusion-group ablation on the IR (analysis: dispatches saved).
    let g = build_resnet_ir(1, 32, 7)?;
    let fused = FusionPass { enabled: true }.plan(&g)?;
    let unfused = FusionPass { enabled: false }.plan(&g)?;
    let mut t = Table::new(
        "Fusion ablation — dispatch groups (IR analysis)",
        &["Config", "Groups", "Dispatches saved"],
    );
    t.row(vec!["fused".into(), fused.group_count().to_string(), "-".into()]);
    t.row(vec![
        "unfused (per-op)".into(),
        unfused.group_count().to_string(),
        format!("{}", unfused.group_count() - fused.group_count()),
    ]);
    t.print();
    Ok(())
}
