//! Quantization pipeline walkthrough on the rust graph IR: build a ResNet
//! IR, calibrate on synthetic data, realize int8, inspect scales and error
//! metrics, then show the layout-alteration pipeline — the full TVM-style
//! compile flow without touching the AOT artifacts.
//!
//! Run: `cargo run --release --example quantize_calibrate`

use anyhow::Result;
use tvmq::graph::passes::{
    calibrate_graph, quantize_graph_with_report, AlterConvLayout, CancelLayoutTransforms,
    ConstantFold, FusionPass, PassManager,
};
use tvmq::graph::{build_resnet_ir, calibrate_ir, evaluate, Op};
use tvmq::metrics::Table;
use tvmq::quant::{abs_max_scale, quant_error};

fn main() -> Result<()> {
    let g = build_resnet_ir(1, 32, 7)?;
    println!(
        "IR: {} nodes, {} KiB of constants",
        g.len(),
        g.const_bytes() / 1024
    );

    // --- Calibration ---
    let calib = calibrate_ir(&g, 42);
    let scales = calibrate_graph(&g, &calib)?;
    let mut t = Table::new(
        "Per-anchor calibration scales (abs-max / 127)",
        &["Node", "Scale", "Weight scale", "Weight SQNR (dB)"],
    );
    for node in &g.nodes {
        if let Some(s) = scales.get(&node.id) {
            let w_node = &g.nodes[node.inputs[1]];
            if let Op::Constant(tvmq::graph::ir::ConstValue::F32(w)) = &w_node.op {
                let sw = abs_max_scale(w);
                let err = quant_error(w, sw);
                t.row(vec![
                    node.name.clone(),
                    format!("{s:.5}"),
                    format!("{sw:.5}"),
                    format!("{:.1}", err.sqnr_db),
                ]);
            }
        }
    }
    t.print();

    // --- Realize + end-to-end quality ---
    let eval = calibrate_ir(&g, 77);
    let (qg, sqnr) = quantize_graph_with_report(&g, &calib, &eval)?;
    println!(
        "realized int8 graph: {} -> {} nodes, output SQNR {:.1} dB",
        g.len(), qg.len(), sqnr
    );
    let f_cls = evaluate(&g, &eval)?.argmax_last()?;
    let q_cls = evaluate(&qg, &eval)?.argmax_last()?;
    println!("fp32 class {:?} vs int8 class {:?}", f_cls, q_cls);

    // --- Layout + fusion pipeline ---
    let pm = PassManager::new()
        .add(AlterConvLayout { c_block: 16, k_block: 16 })
        .add(CancelLayoutTransforms)
        .add(ConstantFold);
    let packed = pm.run(&g)?;
    let fused = FusionPass { enabled: true }.plan(&g)?;
    let unfused = FusionPass { enabled: false }.plan(&g)?;
    println!(
        "layout pipeline: {} -> {} nodes; fusion: {} groups (vs {} per-op dispatches)",
        g.len(), packed.len(), fused.group_count(), unfused.group_count()
    );
    println!("quantize_calibrate OK");
    Ok(())
}
