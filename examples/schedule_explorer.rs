//! Schedule explorer — sweep every (layout, schedule, precision) variant the
//! artifact set provides, in the spirit of the paper's §3.2 analysis: print
//! measured time, the analytic ideal speedup, and the executor counters, so
//! the non-orthogonality of schedule choices is visible in one table.
//!
//! Run: `cargo run --release --example schedule_explorer -- [--epochs 40]`

use anyhow::Result;
use tvmq::executor::{
    EngineKind, EngineSpec, Executor, GraphExecutor, LayoutTag, Precision, Schedule,
};
use tvmq::manifest::Manifest;
use tvmq::metrics::{fmt_ms, measure, Table};
use tvmq::perfmodel::{int8_alu_factor, schedule_table, MachineModel};
use tvmq::runtime::{synthetic_images, Runtime};
use tvmq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse()?;
    let epochs = args.usize("epochs", 40)?;
    let artifacts = tvmq::default_artifacts_dir();
    let m = Manifest::load(&artifacts)?;
    let rt = std::rc::Rc::new(Runtime::new()?);
    let machine = MachineModel::default();
    let ideals = schedule_table(&machine);

    let mut t = Table::new(
        "Schedule explorer (batch 1, graph executor)",
        &["Layout", "Schedule", "Precision", "Measured (ms)", "A72-proj (ms)",
          "Ideal", "Roofline note"],
    );
    for (i, spec) in [
        (LayoutTag::Nchw, Schedule::SpatialPack, Precision::Fp32),
        (LayoutTag::Nchw, Schedule::SpatialPack, Precision::Int8),
        (LayoutTag::Nchw, Schedule::Simd, Precision::Int8),
        (LayoutTag::Nhwc, Schedule::SpatialPack, Precision::Fp32),
        (LayoutTag::Nhwc, Schedule::Interleaved, Precision::Int8),
    ]
    .into_iter()
    .map(|(layout, schedule, precision)| {
        EngineSpec::new(EngineKind::Graph)
            .layout(layout)
            .schedule(schedule)
            .precision(precision)
    })
    .enumerate()
    {
        let bundle = m.find(spec, 1)?;
        let exec = GraphExecutor::new(rt.clone(), &m, bundle)?;
        let rest = if spec.layout == LayoutTag::Nhwc {
            vec![m.image_size, m.image_size, m.in_channels]
        } else {
            vec![m.in_channels, m.image_size, m.image_size]
        };
        let x = synthetic_images(1, &rest, 42);
        let stats = measure(epochs, epochs / 5, || exec.run(&x).map(|_| ()))?;
        let proj = if spec.precision == Precision::Int8 {
            stats.mean_ms / int8_alu_factor(&machine)
        } else {
            stats.mean_ms
        };
        let note = if ideals[i].ideal_speedup >= 16 {
            "vector int8 dot (vmlal/MMLA class)"
        } else {
            "H-parallel only, no reduction vectorization"
        };
        t.row(vec![
            spec.layout.to_string(), spec.schedule.to_string(), spec.precision.to_string(),
            fmt_ms(stats.mean_ms), fmt_ms(proj),
            format!("{}x", ideals[i].ideal_speedup), note.into(),
        ]);
    }
    t.print();
    println!(
        "(A72-proj divides int8 rows by the vmlal ALU factor {}x — the one\n\
         mechanism the CPU substrate cannot execute; see DESIGN.md)",
        int8_alu_factor(&machine)
    );
    Ok(())
}
