//! Quickstart — the end-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Loads the AOT-compiled quantized ResNet artifacts, starts the batching
//! inference server (graph executor, int8 best schedule), drives it with
//! concurrent synthetic clients, and reports latency/throughput plus the
//! executor-contrast sanity check the paper's Table 1 is built on.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use tvmq::coordinator::{InferenceServer, ServeConfig};
use tvmq::executor::{EngineKind, EngineSpec, Executor, GraphExecutor, VmExecutor};
use tvmq::manifest::Manifest;
use tvmq::runtime::{synthetic_images, Runtime, TensorData};

fn main() -> Result<()> {
    let artifacts = tvmq::default_artifacts_dir();
    let m = Manifest::load(&artifacts)?;
    println!(
        "model: {} @ {}px, {} params, {} artifact bundles",
        m.arch, m.image_size, m.param_count, m.bundles.len()
    );

    // --- 1. Single inference through both executors (the paper's contrast) ---
    let rt = std::rc::Rc::new(Runtime::new()?);
    let x = synthetic_images(1, &[m.in_channels, m.image_size, m.image_size], 42);

    // The paper's best variant (NCHW/spatial_pack/int8) under each engine.
    let graph = GraphExecutor::new(
        rt.clone(), &m, m.find(EngineSpec::new(EngineKind::Graph), 1)?,
    )?;
    let vm = VmExecutor::new(
        rt.clone(), &m, m.find(EngineSpec::new(EngineKind::Vm), 1)?,
    )?;
    let t0 = Instant::now();
    let lg = graph.run(&x)?;
    let graph_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let lv = vm.run(&x)?;
    let vm_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "graph executor: {:.2} ms (1 dispatch)   vm executor: {:.2} ms ({} dispatches, {} dynamic allocs)",
        graph_ms, vm_ms,
        vm.counters().dispatches, vm.counters().dynamic_allocs
    );
    assert_eq!(lg.argmax_last()?, lv.argmax_last()?, "executors disagree");

    // --- 2. Batched serving (the memory-bound regime of Table 3) ---
    let server = Arc::new(InferenceServer::start(
        artifacts.clone(),
        ServeConfig {
            spec: EngineSpec::new(EngineKind::Graph),
            max_batch: 64,
            batch_timeout: Duration::from_millis(2),
            ..ServeConfig::default()
        },
    )?);
    println!("serving with batch buckets {:?}", server.buckets);

    let clients = 16usize;
    let per_client = 32usize;
    let t2 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let rest = vec![m.in_channels, m.image_size, m.image_size];
        handles.push(std::thread::spawn(move || -> Result<Vec<usize>> {
            let mut classes = Vec::new();
            for i in 0..per_client {
                let img: TensorData = synthetic_images(1, &rest, (c * 1000 + i) as u64);
                classes.push(s.submit_blocking(img)?.class);
            }
            Ok(classes)
        }));
    }
    let mut served = 0usize;
    for h in handles {
        served += h.join().expect("client thread")?.len();
    }
    let wall = t2.elapsed().as_secs_f64();
    let stats = server.stats();
    let lat = stats.latency_stats();
    println!(
        "served {served} requests in {wall:.2}s -> {:.1} req/s, mean batch {:.1}",
        served as f64 / wall,
        stats.mean_batch()
    );
    match &lat.stats {
        Some(s) => println!(
            "latency p50/p95/p99: {:.2}/{:.2}/{:.2} ms over {} sample(s)  \
             (batches={}, padded slots={})",
            s.p50_ms, s.p95_ms, s.p99_ms, lat.samples_seen,
            stats.batches, stats.padded_slots
        ),
        None => println!(
            "latency: no settled requests  (batches={}, padded slots={})",
            stats.batches, stats.padded_slots
        ),
    }
    println!("quickstart OK");
    Ok(())
}
