//! Micro-benchmark: load an arbitrary single-function HLO text file and time
//! it on the PJRT CPU client.  Used during the perf pass to compare the
//! runtime's executed speed of individual ops (e.g. int8 vs f32 dots)
//! against the jax-side numbers.
//!
//! Usage:
//!   cargo run --release --example microbench -- <hlo.txt> \
//!       --inputs "1024x512:s8,512x256:s8" [--reps 50]

use anyhow::{bail, Context, Result};
use tvmq::runtime::{DType, TensorData};
use tvmq::util::cli::Args;
use tvmq::util::rng::Rng64;

fn parse_inputs(spec: &str) -> Result<Vec<(Vec<usize>, DType)>> {
    spec.split(',')
        .map(|item| {
            let (dims, dt) = item
                .split_once(':')
                .with_context(|| format!("input spec {item:?}: want DIMSxDIMS:dtype"))?;
            let shape: Vec<usize> = dims
                .split('x')
                .map(|d| d.parse().with_context(|| format!("bad dim {d:?}")))
                .collect::<Result<_>>()?;
            Ok((shape, DType::parse(dt)))
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    let Some(path) = args.subcommand.clone() else {
        bail!("usage: microbench <hlo.txt> --inputs SHAPE:dtype[,..] [--reps 50]");
    };
    let inputs = parse_inputs(&args.str("inputs", "1024x512:s8,512x256:s8"))?;
    let reps = args.usize("reps", 50)?;

    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow::anyhow!("parse {path}: {e}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("compile: {e}"))?;

    let mut rng = Rng64::seed_from_u64(7);
    let lits: Vec<xla::Literal> = inputs
        .iter()
        .map(|(shape, dt)| {
            let n: usize = shape.iter().product();
            let t = match dt {
                DType::S8 => {
                    let v: Vec<i8> = (0..n).map(|_| rng.i8()).collect();
                    TensorData::from_i8(shape.clone(), &v)
                }
                DType::F32 => {
                    let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                    TensorData::from_f32(shape.clone(), &v)
                }
                DType::S32 => {
                    let v: Vec<i32> = (0..n).map(|_| rng.next_u64() as i32 % 1000).collect();
                    TensorData::from_i32(shape.clone(), &v)
                }
            }?;
            tvmq::runtime::to_literal(&t)
        })
        .collect::<Result<_>>()?;

    // Warmup.
    for _ in 0..3 {
        exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let r = exe.execute::<xla::Literal>(&lits).map_err(|e| anyhow::anyhow!("{e}"))?;
        std::hint::black_box(&r);
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    println!("{path}: {ms:.3} ms/exec over {reps} reps");
    Ok(())
}
