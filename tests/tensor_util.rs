//! TensorData batching invariants, the JSON substrate, quant helpers, and
//! the perfmodel's paper-facing numbers.

use tvmq::perfmodel::{bound_analysis, int8_alu_factor, roofline_ms, schedule_table, MachineModel};
use tvmq::quant::{abs_max_scale, dequantize, quant_error, quantize};
use tvmq::runtime::{synthetic_images, DType, TensorData};
use tvmq::util::json::Json;
use tvmq::util::rng::Rng64;

// ---------------------------------------------------------------------------
// TensorData (the batcher's currency)
// ---------------------------------------------------------------------------

#[test]
fn prop_stack_split_roundtrip() {
    let mut rng = Rng64::seed_from_u64(3);
    for _ in 0..40 {
        let k = rng.range_usize(1, 8);
        let rest: Vec<usize> = vec![rng.range_usize(1, 5), rng.range_usize(1, 5)];
        let items: Vec<TensorData> = (0..k)
            .map(|i| synthetic_images(1, &rest, i as u64))
            .collect();
        let refs: Vec<&TensorData> = items.iter().collect();
        let stacked = TensorData::stack(&refs).unwrap();
        assert_eq!(stacked.shape[0], k);
        let back = stacked.split_rows(1).unwrap();
        assert_eq!(back, items);
    }
}

#[test]
fn pad_then_truncate_is_identity() {
    let t = synthetic_images(3, &[2, 2], 1);
    let padded = t.pad_rows(8).unwrap();
    assert_eq!(padded.shape[0], 8);
    // Padded rows are zeros.
    let z = &padded.as_f32().unwrap()[3 * 4..];
    assert!(z.iter().all(|v| *v == 0.0));
    assert_eq!(padded.truncate_rows(3).unwrap(), t);
}

#[test]
fn stack_rejects_mismatched_items() {
    let a = synthetic_images(1, &[2, 2], 0);
    let b = synthetic_images(1, &[3, 2], 0);
    assert!(TensorData::stack(&[&a, &b]).is_err());
}

#[test]
fn argmax_last_rows() {
    let t = TensorData::from_f32(vec![2, 3], &[0.0, 5.0, 1.0, 9.0, -1.0, 2.0]).unwrap();
    assert_eq!(t.argmax_last().unwrap(), vec![1, 0]);
}

#[test]
fn dtype_sizes_and_tags() {
    assert_eq!(DType::parse("f32").size_bytes(), 4);
    assert_eq!(DType::parse("s8").size_bytes(), 1);
    assert_eq!(DType::parse("s32").size_bytes(), 4);
    assert_eq!(DType::F32.tag(), "f32");
}

#[test]
fn tensor_new_validates_length() {
    assert!(TensorData::new(DType::F32, vec![2, 2], vec![0u8; 15]).is_err());
    assert!(TensorData::new(DType::S8, vec![2, 2], vec![0u8; 4]).is_ok());
}

#[test]
fn zero_copy_views_agree_with_decoded_vectors() {
    let f = TensorData::from_f32(vec![2, 3], &[1.0, -2.5, 0.0, 3.25, -0.5, 9.0]).unwrap();
    assert_eq!(f.as_f32_slice().unwrap(), &f.as_f32().unwrap()[..]);
    let i = TensorData::from_i32(vec![4], &[1, -2, 3, -4]).unwrap();
    assert_eq!(i.as_i32_slice().unwrap(), &i.as_i32().unwrap()[..]);
    let b = TensorData::from_i8(vec![3], &[-1, 0, 127]).unwrap();
    assert_eq!(b.as_i8_slice().unwrap(), &b.as_i8().unwrap()[..]);
    // Dtype mismatch is rejected.
    assert!(f.as_i32_slice().is_err());
    assert!(i.as_f32_slice().is_err());
}

#[test]
fn mutable_views_write_through() {
    let mut t = TensorData::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
    t.as_f32_mut().unwrap()[2] = -7.5;
    assert_eq!(t.as_f32().unwrap(), vec![1.0, 2.0, -7.5, 4.0]);
    let mut q = TensorData::from_i8(vec![2], &[1, 2]).unwrap();
    q.as_i8_mut().unwrap()[0] = -128;
    assert_eq!(q.as_i8().unwrap(), vec![-128, 2]);
}

#[test]
fn abs_max_scale_guards_non_finite_samples() {
    let clean = abs_max_scale(&[0.25, -1.5]);
    let dirty = abs_max_scale(&[0.25, f32::NAN, f32::INFINITY, -1.5]);
    assert_eq!(clean, dirty);
    assert!(dirty.is_finite() && dirty > 0.0);
}

// ---------------------------------------------------------------------------
// JSON substrate
// ---------------------------------------------------------------------------

#[test]
fn json_roundtrip_nested() {
    let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n\"there\"", "d": null}, "e": true}"#;
    let v = Json::parse(text).unwrap();
    assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\n\"there\"");
    assert!(v.get("b").unwrap().opt("d").is_none());
    // Re-serialize and re-parse.
    let again = Json::parse(&v.to_string_pretty()).unwrap();
    assert_eq!(v, again);
}

#[test]
fn json_unicode_and_escapes() {
    let v = Json::parse(r#""café → ☃""#).unwrap();
    assert_eq!(v.as_str().unwrap(), "café → ☃");
    let back = Json::parse(&v.to_string_pretty()).unwrap();
    assert_eq!(v, back);
}

#[test]
fn json_rejects_malformed() {
    for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{\"a\":}"] {
        assert!(Json::parse(bad).is_err(), "accepted malformed {bad:?}");
    }
}

#[test]
fn json_numbers() {
    assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    assert_eq!(Json::parse("-1.5").unwrap().as_f64().unwrap(), -1.5);
    assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    assert!(Json::parse("-2").unwrap().as_usize().is_err());
}

// ---------------------------------------------------------------------------
// Host-side quantization
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_roundtrip_error_bound() {
    let mut rng = Rng64::seed_from_u64(31);
    for _ in 0..30 {
        let vals: Vec<f32> = (0..500).map(|_| rng.normal() * 3.0).collect();
        let s = abs_max_scale(&vals);
        let deq = dequantize(&quantize(&vals, s), s);
        for (a, b) in vals.iter().zip(&deq) {
            assert!((a - b).abs() <= s / 2.0 + 1e-6);
        }
        let err = quant_error(&vals, s);
        assert!(err.sqnr_db > 25.0, "sqnr {}", err.sqnr_db);
    }
}

#[test]
fn quantize_saturates() {
    let q = quantize(&[1e9, -1e9, 0.0], 0.1);
    assert_eq!(q, vec![127, -127, 0]);
}

// ---------------------------------------------------------------------------
// Perfmodel: the paper's ideal-speedup arithmetic
// ---------------------------------------------------------------------------

#[test]
fn ideal_speedups_match_paper_table2() {
    let m = MachineModel::default();
    let t = schedule_table(&m);
    let ideals: Vec<usize> = t.iter().map(|d| d.ideal_speedup).collect();
    assert_eq!(ideals, vec![16, 16, 16, 4, 16], "Table 2 Ideal Speedup column");
}

#[test]
fn alu_factor_is_vmlal_width_ratio() {
    assert_eq!(int8_alu_factor(&MachineModel::default()), 4.0);
}

#[test]
fn roofline_monotonic_and_int8_faster_in_compute_regime() {
    let m = MachineModel::default();
    let flops = 1e9;
    let small_bytes = 1e3;
    assert!(roofline_ms(&m, flops, small_bytes, true) < roofline_ms(&m, flops, small_bytes, false));
    // In the bandwidth regime both precisions converge to the same wall.
    let big_bytes = 1e12;
    assert_eq!(
        roofline_ms(&m, 1.0, big_bytes, true),
        roofline_ms(&m, 1.0, big_bytes, false)
    );
}

#[test]
fn bound_analysis_crossover_with_batch() {
    let m = MachineModel::default();
    let rows = bound_analysis(&m, 32, 300_000.0, &[1, 16, 64, 256], false);
    // Memory share must grow with batch faster than... both scale linearly in
    // batch for activations; weights amortize: the mem/compute ratio is
    // non-decreasing in batch.
    let ratio: Vec<f64> = rows.iter().map(|(_, c, me)| me / c).collect();
    for w in ratio.windows(2) {
        assert!(w[1] <= w[0] * 1.0001 || w[1] >= w[0] * 0.9999); // sanity: finite
    }
    assert!(rows.iter().all(|(_, c, me)| *c > 0.0 && *me > 0.0));
}
