//! Measurement protocol, table rendering, and bench-harness plumbing.

use tvmq::executor::{EngineKind, EngineSpec, Precision};
use tvmq::metrics::{improvement_pct, measure, EpochStats, Table};

#[test]
fn epoch_stats_discard_warmup() {
    // Warm-up samples are 10× slower; they must not pollute the mean.
    let samples: Vec<f64> = (0..110)
        .map(|i| if i < 10 { 100.0 } else { 10.0 })
        .collect();
    let s = EpochStats::from_samples(&samples, 10).expect("post-warmup epochs exist");
    assert_eq!(s.epochs, 110);
    assert!((s.mean_ms - 10.0).abs() < 1e-9);
    assert_eq!(s.std_ms, 0.0);
    assert_eq!(s.p50_ms, 10.0);
}

#[test]
fn epoch_stats_percentiles_ordered() {
    let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
    let s = EpochStats::from_samples(&samples, 0).expect("non-empty samples");
    assert!(s.min_ms <= s.p50_ms && s.p50_ms <= s.p95_ms);
    assert!(s.p95_ms <= s.p99_ms && s.p99_ms <= s.max_ms);
    assert_eq!(s.min_ms, 1.0);
    assert_eq!(s.max_ms, 100.0);
}

#[test]
fn epoch_stats_degenerate_series_are_typed_not_zero() {
    // Warmup swallowing every sample used to yield silent zeros; now the
    // degenerate cases are a typed None the caller must handle.
    assert!(EpochStats::from_samples(&[5.0], 10).is_none(), "warmup > len");
    assert!(EpochStats::from_samples(&[], 0).is_none(), "empty series");
}

#[test]
fn improvement_matches_paper_semantics() {
    // Paper: 13.29 ms baseline, 8.27 ms quantized => 160.70%.
    let imp = improvement_pct(13.29, 8.27);
    assert!((imp - 160.70).abs() < 0.1, "got {imp}");
    // Slower-than-baseline yields < 100% (Table 1's 45.52% row).
    let slow = improvement_pct(13.29, 29.19);
    assert!((slow - 45.53).abs() < 0.1, "got {slow}");
}

#[test]
fn measure_runs_closure_epochs_times() {
    let mut n = 0u32;
    let s = measure(20, 5, || {
        n += 1;
        Ok(())
    })
    .unwrap();
    assert_eq!(n, 20);
    assert_eq!(s.warmup, 5);
    assert!(s.mean_ms >= 0.0);
}

#[test]
fn measure_propagates_errors() {
    let r = measure(5, 1, || anyhow::bail!("boom"));
    assert!(r.is_err());
}

#[test]
fn table_markdown_and_csv_shapes() {
    let mut t = Table::new("T", &["a", "b"]);
    t.row(vec!["1".into(), "x,y".into()]);
    t.row(vec!["22".into(), "z".into()]);
    let md = t.to_markdown();
    assert!(md.contains("### T"));
    assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4); // header + sep + 2 rows
    let csv = t.to_csv();
    assert_eq!(csv.lines().count(), 3);
    assert!(csv.starts_with("a,b"));
}

#[test]
#[should_panic(expected = "row arity")]
fn table_rejects_wrong_arity() {
    let mut t = Table::new("T", &["a", "b"]);
    t.row(vec!["only-one".into()]);
}

#[test]
fn quant_footprint_reflects_precision() {
    // int8 bundles carry 4x fewer weight bytes but extra q/dq staging —
    // verified against the real manifest if artifacts exist.
    let dir = tvmq::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return; // unit-test environments without artifacts
    }
    let m = tvmq::manifest::Manifest::load(&dir).unwrap();
    let f = m
        .find(EngineSpec::new(EngineKind::Graph).precision(Precision::Fp32), 1)
        .unwrap();
    let q = m.find(EngineSpec::new(EngineKind::Graph), 1).unwrap();
    assert_eq!(f.weight_bytes, 4 * q.weight_bytes);
    let ff = tvmq::quant::footprint(&m, f);
    let qf = tvmq::quant::footprint(&m, q);
    assert!(qf.weight_bytes < ff.weight_bytes);
    // §3.2.2: the paper's int8 rows use slightly MORE total memory at equal
    // batch; our model reflects the q/dq staging overhead.
    assert!(qf.qdq_overhead_bytes > 0 || q.executor == EngineKind::Graph);
}

#[test]
fn bandwidth_model_scales_with_batch() {
    let dir = tvmq::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let m = tvmq::manifest::Manifest::load(&dir).unwrap();
    let b1 = m.find(EngineSpec::new(EngineKind::Graph), 1).unwrap();
    let b64 = m.find(EngineSpec::new(EngineKind::Graph), 64).unwrap();
    let w1 = tvmq::quant::bandwidth(b1);
    let w64 = tvmq::quant::bandwidth(b64);
    assert_eq!(w1.weight_bytes, w64.weight_bytes, "weights amortize");
    assert!(w64.activation_bytes > 32 * w1.activation_bytes);
}
