//! Integration tests over the real AOT artifacts + PJRT runtime: executor
//! equivalence, coordinator serving, failure injection.
//!
//! Requires `make artifacts` to have run (the Makefile's `test` target
//! guarantees it).  Tier-1 triage: the offline build links the stub `xla`
//! crate and ships no artifacts, so every test needing either is
//! `#[ignore]`d with a reason; run them with `cargo test -- --ignored`
//! on a host with the real PJRT bridge.  The artifact-free failure
//! injection test (`poisoned_manifest_rejected`) still runs.

use std::rc::Rc;
use std::time::Duration;

use tvmq::coordinator::{InferenceServer, ServeConfig};
use tvmq::executor::{
    EngineKind, EngineSpec, Executor, GraphExecutor, LayoutTag, Precision, Schedule,
    VmExecutor,
};
use tvmq::manifest::Manifest;
use tvmq::runtime::{synthetic_images, Runtime, TensorData};

fn artifacts() -> std::path::PathBuf {
    let dir = tvmq::default_artifacts_dir();
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    dir
}

/// NCHW/spatial_pack/int8 (the paper's best variant) under an engine.
fn best(engine: EngineKind) -> EngineSpec {
    EngineSpec::new(engine)
}

/// The five Table-2 graph-engine combos.
fn table2_specs() -> [EngineSpec; 5] {
    [
        (LayoutTag::Nchw, Schedule::SpatialPack, Precision::Fp32),
        (LayoutTag::Nchw, Schedule::SpatialPack, Precision::Int8),
        (LayoutTag::Nchw, Schedule::Simd, Precision::Int8),
        (LayoutTag::Nhwc, Schedule::SpatialPack, Precision::Fp32),
        (LayoutTag::Nhwc, Schedule::Interleaved, Precision::Int8),
    ]
    .map(|(layout, schedule, precision)| {
        EngineSpec::new(EngineKind::Graph)
            .layout(layout)
            .schedule(schedule)
            .precision(precision)
    })
}

fn image(m: &Manifest, batch: usize, layout: LayoutTag, seed: u64) -> TensorData {
    // Only NHWC is channels-last; NCHW and packed NCHWc both take plain
    // NCHW images (the packed stem is unblocked).
    let rest = if layout == LayoutTag::Nhwc {
        vec![m.image_size, m.image_size, m.in_channels]
    } else {
        vec![m.in_channels, m.image_size, m.image_size]
    };
    synthetic_images(batch, &rest, seed)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0f32, |m, (x, y)| m.max((x - y).abs()))
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn manifest_loads_and_validates() {
    let m = Manifest::load(artifacts()).unwrap();
    assert!(m.bundles.len() >= 10);
    assert!(m.param_count > 100_000);
    assert!(!m.scales.is_empty());
    // Every Table-2 combo exists as a graph bundle at batch 1.
    for spec in table2_specs() {
        m.find(spec, 1).unwrap();
    }
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn graph_and_vm_executors_agree() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let x = image(&m, 1, LayoutTag::Nchw, 7);

    let gb = m.find(best(EngineKind::Graph), 1).unwrap();
    let vb = m.find(best(EngineKind::Vm), 1).unwrap();
    let ge = GraphExecutor::new(rt.clone(), &m, gb).unwrap();
    let ve = VmExecutor::new(rt.clone(), &m, vb).unwrap();

    let a = ge.run(&x).unwrap().as_f32().unwrap();
    let b = ve.run(&x).unwrap().as_f32().unwrap();
    // Same math, different fusion: tolerate f32 reassociation only.
    assert!(max_abs_diff(&a, &b) < 1e-3, "executors diverged");

    // Counters expose the mechanistic contrast.
    let gc = ge.counters();
    let vc = ve.counters();
    assert_eq!(gc.dispatches, 1);
    assert_eq!(gc.dynamic_allocs, 0);
    assert!(vc.dispatches > 10, "vm must dispatch per primitive");
    assert!(vc.dynamic_allocs > 10);
    assert!(vc.boundary_bytes > 0);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn vm_device_chaining_agrees_with_host_path() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let x = image(&m, 1, LayoutTag::Nchw, 9);
    let vb = m.find(best(EngineKind::Vm), 1).unwrap();
    let host = VmExecutor::with_options(rt.clone(), &m, vb, false).unwrap();
    let dev = VmExecutor::with_options(rt.clone(), &m, vb, true).unwrap();
    let a = host.run(&x).unwrap().as_f32().unwrap();
    let b = dev.run(&x).unwrap().as_f32().unwrap();
    assert_eq!(a, b, "device chaining changed results");
    assert_eq!(dev.counters().boundary_bytes, 0);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn int8_tracks_fp32_model() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let x = image(&m, 1, LayoutTag::Nchw, 21);
    let f = GraphExecutor::new(
        rt.clone(), &m,
        m.find(best(EngineKind::Graph).precision(Precision::Fp32), 1).unwrap(),
    )
    .unwrap();
    let q = GraphExecutor::new(
        rt.clone(), &m, m.find(best(EngineKind::Graph), 1).unwrap(),
    )
    .unwrap();
    let lf = f.run(&x).unwrap();
    let lq = q.run(&x).unwrap();
    // Quantization noise is bounded; classes agree on this seed.
    assert_eq!(lf.argmax_last().unwrap(), lq.argmax_last().unwrap());
    let (a, b) = (lf.as_f32().unwrap(), lq.as_f32().unwrap());
    assert!(max_abs_diff(&a, &b) < 1.0, "int8 drifted too far from fp32");
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn all_table2_variants_execute_and_agree_on_class() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let mut classes = Vec::new();
    for spec in table2_specs() {
        let e = GraphExecutor::new(rt.clone(), &m, m.find(spec, 1).unwrap()).unwrap();
        let logits = e.run(&image(&m, 1, spec.layout, 33)).unwrap();
        classes.push(logits.argmax_last().unwrap()[0]);
    }
    assert!(
        classes.windows(2).all(|w| w[0] == w[1]),
        "schedules disagree on the predicted class: {classes:?}"
    );
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn batch_variants_consistent_with_batch1() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let buckets = m.batch_buckets(best(EngineKind::Graph));
    assert!(buckets.len() >= 3, "need several buckets, have {buckets:?}");
    let b1 = GraphExecutor::new(
        rt.clone(), &m, m.find(best(EngineKind::Graph), 1).unwrap(),
    )
    .unwrap();
    let x1 = image(&m, 1, LayoutTag::Nchw, 5);
    let want = b1.run(&x1).unwrap().as_f32().unwrap();

    let bb = buckets[1];
    let eb = GraphExecutor::new(
        rt.clone(), &m, m.find(best(EngineKind::Graph), bb).unwrap(),
    )
    .unwrap();
    let xb = x1.pad_rows(bb).unwrap();
    let got_all = eb.run(&xb).unwrap();
    let got = got_all.truncate_rows(1).unwrap().as_f32().unwrap();
    assert!(
        max_abs_diff(&want, &got) < 1e-3,
        "same image through a bigger bucket changed logits"
    );
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn executor_rejects_wrong_shape() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let e = GraphExecutor::new(
        rt, &m, m.find(best(EngineKind::Graph), 1).unwrap(),
    )
    .unwrap();
    let bad = synthetic_images(1, &[1, 4, 4], 0);
    assert!(e.run(&bad).is_err());
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn executable_cache_hits_on_reload() {
    let m = Manifest::load(artifacts()).unwrap();
    let rt = Rc::new(Runtime::new().unwrap());
    let b = m.find(best(EngineKind::Graph), 1).unwrap();
    let _e1 = GraphExecutor::new(rt.clone(), &m, b).unwrap();
    let compiles_before = rt.cached_modules();
    let _e2 = GraphExecutor::new(rt.clone(), &m, b).unwrap();
    assert_eq!(rt.cached_modules(), compiles_before, "second load must hit the cache");
}

// ---------------------------------------------------------------------------
// Failure injection
// ---------------------------------------------------------------------------

#[test]
fn poisoned_manifest_rejected() {
    let dir = tempdir("tvmq-poison");
    std::fs::write(dir.join("manifest.json"), "{\"version\": 1}").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), "not json at all").unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn missing_hlo_file_rejected() {
    // Copy the manifest but not the HLO files: validation must fail.
    let src = artifacts();
    let dir = tempdir("tvmq-missing-hlo");
    std::fs::copy(src.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let err = Manifest::load(&dir).unwrap_err().to_string();
    assert!(err.contains("missing HLO"), "unexpected error: {err}");
}

fn tempdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn server_serves_concurrent_clients() {
    let m = Manifest::load(artifacts()).unwrap();
    let server = InferenceServer::start(
        artifacts(),
        ServeConfig {
            max_batch: 16,
            batch_timeout: Duration::from_millis(3),
            ..Default::default()
        },
    )
    .unwrap();
    let server = std::sync::Arc::new(server);

    let mut handles = Vec::new();
    for c in 0..8u64 {
        let s = server.clone();
        let rest = vec![m.in_channels, m.image_size, m.image_size];
        handles.push(std::thread::spawn(move || {
            let mut classes = Vec::new();
            for i in 0..6u64 {
                let img = synthetic_images(1, &rest, c * 100 + i);
                let reply = s.submit_blocking(img).expect("inference reply");
                assert_eq!(reply.logits.shape[0], 1);
                classes.push(reply.class);
            }
            classes
        }));
    }
    let mut total = 0;
    for h in handles {
        total += h.join().unwrap().len();
    }
    assert_eq!(total, 48, "every request must be answered exactly once");

    let stats = server.stats();
    assert_eq!(stats.requests, 48);
    assert!(stats.batches <= 48);
    assert!(stats.batch_histogram.keys().all(|b| server.buckets.contains(b)));
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn server_single_request_matches_direct_execution() {
    let m = Manifest::load(artifacts()).unwrap();
    let server = InferenceServer::start(
        artifacts(),
        ServeConfig {
            max_batch: 1,
            batch_timeout: Duration::from_millis(0),
            ..Default::default()
        },
    )
    .unwrap();
    let x = image(&m, 1, LayoutTag::Nchw, 77);
    let reply = server.submit_blocking(x.clone()).unwrap();

    let rt = Rc::new(Runtime::new().unwrap());
    let e = GraphExecutor::new(
        rt, &m, m.find(best(EngineKind::Graph), 1).unwrap(),
    )
    .unwrap();
    let direct = e.run(&x).unwrap();
    assert_eq!(reply.logits.as_f32().unwrap(), direct.as_f32().unwrap());
}

#[test]
fn unknown_variant_tokens_fail_at_parse_time() {
    // Free-form strings no longer reach the server: a typo'd schedule is
    // a parse error, not a late "no bundle" miss.
    assert!("nonexistent".parse::<Schedule>().is_err());
    assert!("NCHW/nonexistent/int8/graph".parse::<EngineSpec>().is_err());
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a real PJRT backend; the offline build ships the xla stub"]
fn server_rejects_variant_without_bundles() {
    // Parses fine, but no artifact bundle exists for the reference
    // schedule under the graph engine: startup must fail.
    let cfg = ServeConfig {
        spec: best(EngineKind::Graph).schedule(Schedule::Reference).precision(Precision::Int8),
        ..Default::default()
    };
    assert!(InferenceServer::start(artifacts(), cfg).is_err());
}
