//! Arena serving end-to-end: the coordinator over `NativeArenaFactory`
//! must return **bit-identical** logits to the interpreter oracle for the
//! same image, whichever bucket the request is served in.
//!
//! Why this holds: every arena kernel (and every interpreter kernel) is
//! per-sample independent — conv/dense/pool/quantize never mix batch
//! rows — and the factory calibrates int8 scales once on the batch-1
//! graph and reuses them for every bucket.  So padding rows and batch
//! neighbors cannot perturb a request's logits, and the serving tier can
//! be checked against `graph::interp::evaluate` exactly, with no
//! tolerance.

use std::time::Duration;

use tvmq::coordinator::{InferenceServer, PendingReply, ServeConfig};
use tvmq::executor::{
    EngineKind, EngineSpec, NativeArenaFactory, Precision,
};
use tvmq::graph::evaluate;
use tvmq::runtime::TensorData;
use tvmq::util::rng::Rng64;

const IMAGE: usize = 16;
const BUCKETS: [usize; 3] = [1, 4, 8];

/// A seeded [1, 3, IMAGE, IMAGE] image (same normal-ish distribution the
/// IR calibration inputs use).
fn seeded_image(seed: u64) -> TensorData {
    let mut rng = Rng64::seed_from_u64(seed);
    let vals: Vec<f32> = (0..3 * IMAGE * IMAGE).map(|_| rng.normal() * 0.5).collect();
    TensorData::from_f32(vec![1, 3, IMAGE, IMAGE], &vals).unwrap()
}

fn serve_and_check(precision: Precision) {
    let spec = EngineSpec::new(EngineKind::Arena).precision(precision);
    let factory = NativeArenaFactory::new(spec, &BUCKETS, IMAGE, 1).unwrap();
    // The oracle: the interpreter over the exact batch-1 graph the factory
    // compiles (same weights, same shared quantization scales).
    let oracle_graph = factory.graph(1).unwrap();

    let server = InferenceServer::start_with(
        factory,
        ServeConfig {
            spec,
            max_batch: 8,
            // Generous: each group below must gather into one batch.
            batch_timeout: Duration::from_millis(150),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert_eq!(server.buckets, BUCKETS.to_vec());

    // One group per bucket size: n concurrent requests gather into a
    // batch of n and serve in bucket n.
    let mut seed = 0u64;
    for group in BUCKETS {
        let images: Vec<TensorData> = (0..group)
            .map(|_| {
                seed += 1;
                seeded_image(seed)
            })
            .collect();
        let pending: Vec<PendingReply> = images
            .iter()
            .map(|img| server.submit(img.clone()).unwrap())
            .collect();
        for (img, p) in images.iter().zip(pending) {
            let reply = p.wait().unwrap();
            assert_eq!(
                reply.batch, group,
                "{precision}: group of {group} should serve in bucket {group}"
            );
            let want = evaluate(&oracle_graph, img).unwrap();
            let (got, want) = (reply.logits.as_f32().unwrap(), want.as_f32().unwrap());
            // Bit-identical, not approximately equal.
            let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                got_bits, want_bits,
                "{precision}: served logits diverged from the interpreter oracle \
                 in bucket {group}"
            );
            let want_class = want
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            assert_eq!(reply.class, want_class);
        }
    }

    let stats = server.stats();
    assert_eq!(stats.requests, (1 + 4 + 8) as u64);
    assert_eq!(stats.errors, 0);
    // Every bucket actually exercised.
    for b in BUCKETS {
        assert_eq!(
            stats.batch_histogram.get(&b),
            Some(&1),
            "bucket {b} histogram: {:?}",
            stats.batch_histogram
        );
    }
    assert_eq!(stats.padded_slots, 0);
    server.shutdown().unwrap();
}

#[test]
fn arena_serving_matches_interp_oracle_across_buckets_fp32() {
    serve_and_check(Precision::Fp32);
}

#[test]
fn arena_serving_matches_interp_oracle_across_buckets_int8() {
    serve_and_check(Precision::Int8);
}

/// The bucket-invariance claim itself: the same image served alone
/// (bucket 1) and served in the largest bucket yields the same bits.
#[test]
fn same_image_is_bucket_invariant() {
    let spec = EngineSpec::new(EngineKind::Arena);
    let factory = NativeArenaFactory::new(spec, &BUCKETS, IMAGE, 1).unwrap();
    let server = InferenceServer::start_with(
        factory,
        ServeConfig {
            spec,
            max_batch: 8,
            batch_timeout: Duration::from_millis(150),
            ..ServeConfig::default()
        },
    )
    .unwrap();

    let img = seeded_image(424242);
    let solo = server.submit_blocking(img.clone()).unwrap();
    assert_eq!(solo.batch, 1);

    // Ride along with 7 sibling requests → bucket 8.
    let pending: Vec<PendingReply> = (0..8)
        .map(|i| {
            let x = if i == 0 { img.clone() } else { seeded_image(900 + i) };
            server.submit(x).unwrap()
        })
        .collect();
    let mut replies: Vec<_> = pending.into_iter().map(|p| p.wait().unwrap()).collect();
    let grouped = replies.remove(0);
    assert_eq!(grouped.batch, 8);
    assert_eq!(
        solo.logits.as_f32().unwrap(),
        grouped.logits.as_f32().unwrap(),
        "logits changed with the serving bucket"
    );
    server.shutdown().unwrap();
}

/// The sharded tier preserves the oracle contract: with 3 workers each
/// holding its own per-bucket engine set, every concurrently-served
/// request returns logits bit-identical to the interpreter — whichever
/// worker and whichever bucket served it.
#[test]
fn multi_worker_serving_is_bit_identical_to_oracle() {
    let spec = EngineSpec::new(EngineKind::Arena).precision(Precision::Int8);
    let factory = NativeArenaFactory::new(spec, &BUCKETS, IMAGE, 1).unwrap();
    let oracle_graph = factory.graph(1).unwrap();

    let server = std::sync::Arc::new(
        InferenceServer::start_with(
            factory,
            ServeConfig {
                spec,
                max_batch: 8,
                // Short: let batches form per-worker rather than forcing
                // one big gather, so several workers serve concurrently.
                batch_timeout: Duration::from_millis(5),
                workers: 3,
                ..ServeConfig::default()
            },
        )
        .unwrap(),
    );
    assert_eq!(server.workers(), 3);

    // 24 requests from 4 client threads, each checked bit-exactly
    // against its own interpreter run.
    let clients: Vec<_> = (0..4)
        .map(|t| {
            let server = std::sync::Arc::clone(&server);
            let oracle_graph = oracle_graph.clone();
            std::thread::spawn(move || {
                for i in 0..6 {
                    let img = seeded_image(1000 + (t * 6 + i) as u64);
                    let reply = server.submit_blocking(img.clone()).unwrap();
                    let want = evaluate(&oracle_graph, &img).unwrap();
                    let got_bits: Vec<u32> =
                        reply.logits.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
                    let want_bits: Vec<u32> =
                        want.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(
                        got_bits, want_bits,
                        "worker-served logits diverged from the oracle (client {t}, req {i})"
                    );
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    let stats = server.stats();
    assert_eq!(stats.requests, 24);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.shed, 0);
    std::sync::Arc::try_unwrap(server)
        .ok()
        .expect("clients joined")
        .shutdown()
        .unwrap();
}
