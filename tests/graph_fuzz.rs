//! Randomized differential harness: seeded generator of valid mixed
//! fp32/int8 graphs (conv / dense / bias / relu / residual add / pool
//! chains) across **all three layouts** — each stage picks NCHW, NHWC, or
//! channel-blocked NCHW{c}, with explicit layout-cast nodes wherever
//! consecutive stages disagree — each executed by `ArenaExec::run_into`,
//! fused and unfused, and compared **bit-for-bit** (`TensorData` equality
//! is raw bytes) against the `graph::interp::evaluate` oracle across
//! thread counts 1 / 2 / 4 (plus `TVMQ_THREADS`, which the CI pool-path
//! job sets).
//!
//! This is what pins the layout-complete fusion layer: fp32 epilogues,
//! two-input residual steps in both positions (pre- and post-relu, both
//! operand orders), quantized chains in every layout (including the
//! packed int8 kernels' stack-lane accumulation), mixed-layout graphs,
//! and the persistent worker pool all get exercised by the same 200-seed
//! corpus on every run.

use tvmq::executor::ArenaExec;
use tvmq::graph::ir::{dims_of, shape_of};
use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use tvmq::graph::{calibrate_ir, evaluate, Graph, Layout, NodeId, Op, TensorTy};
use tvmq::runtime::TensorData;
use tvmq::util::rng::Rng64;

/// Fixed seed set: seeds `BASE ^ 0 .. BASE ^ 199`, fully deterministic.
const BASE_SEED: u64 = 0x9d5a_b5e1_7c3f_0211;
const CASES: u64 = 200;

/// Thread counts under test; `TVMQ_THREADS` adds an extra width so CI can
/// force the pool path without editing the seed corpus.
fn thread_counts() -> Vec<usize> {
    let mut t = vec![1usize, 2, 4];
    if let Ok(v) = std::env::var("TVMQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 && !t.contains(&n) {
                t.push(n);
            }
        }
    }
    t
}

/// Residual add with randomized operand order (both orders must fuse and
/// stay bit-exact — float addition is not bit-commutative for NaN, so the
/// executor preserves the graph's order).
fn add_residual(g: &mut Graph, rng: &mut Rng64, name: String, t: NodeId, skip: NodeId) -> NodeId {
    let inputs = if rng.bool() { vec![t, skip] } else { vec![skip, t] };
    g.add(name, Op::Add, inputs).unwrap()
}

/// Channel palette: deliberately ragged.  4 and 8 host every block
/// width; 6 only blocks by 2; 5 blocks by nothing — so conv reduction
/// spans (`c·r·s`) and output-channel counts routinely land off the
/// register tile (k-tail, n-tail) and off the NCHW{c} block widths.
const CHANNELS: [usize; 4] = [4, 5, 6, 8];

/// Draw a layout a stage with `c` running channels can host: the
/// unblocked families always, a channel-blocked NCHW{c} only when the
/// block width divides `c` (ragged counts fall back to NCHW/NHWC).
fn rand_layout_for(rng: &mut Rng64, c: usize) -> Layout {
    let mut choices = vec![Layout::Nchw, Layout::Nhwc];
    for cb in [2usize, 4] {
        if c % cb == 0 {
            choices.push(Layout::Nchwc(cb));
        }
    }
    choices[rng.range_usize(0, choices.len() - 1)]
}

/// A random conv weight constant in `layout`'s weight format (OIHW /
/// HWIO / OIHW{i}{o}); the values are a fresh draw, the *shape* is what's
/// under test.
fn add_weight(
    g: &mut Graph,
    rng: &mut Rng64,
    name: String,
    cout: usize,
    cin: usize,
    k: usize,
    layout: Layout,
) -> NodeId {
    let vals: Vec<f32> = (0..cout * cin * k * k).map(|_| rng.normal() * 0.3).collect();
    let shape = match layout {
        Layout::Nchw => vec![cout, cin, k, k],
        Layout::Nhwc => vec![k, k, cin, cout],
        Layout::Nchwc(cb) => vec![cout / cb, cin / cb, k, k, cb, cb],
    };
    g.add_const_f32(name, shape, vals).unwrap()
}

/// A random mixed-layout net: stacked conv stages — each in its own
/// layout, bridged by explicit `LayoutTransform` casts — with optional
/// bias / relu / residual (pre- or post-relu) / maxpool, closed by
/// gap + dense (+ optional relu).
fn random_graph(rng: &mut Rng64) -> Graph {
    let mut g = Graph::new();
    let batch = rng.range_usize(1, 2);
    let mut image = rng.range_usize(5, 9);
    let mut c = CHANNELS[rng.range_usize(0, CHANNELS.len() - 1)];
    let mut layout = rand_layout_for(rng, c);
    let x = g.add_input("x", TensorTy::f32(shape_of(batch, c, image, image, layout)));
    let mut cur = x;
    for i in 0..rng.range_usize(1, 3) {
        // Mixed-layout coverage: hop to a fresh layout through a cast node
        // whenever the draw disagrees with the running tensor's layout.
        let next = rand_layout_for(rng, c);
        if next != layout {
            cur = g
                .add(
                    format!("c{i}.cast"),
                    Op::LayoutTransform { from: layout, to: next },
                    vec![cur],
                )
                .unwrap();
            layout = next;
        }
        let kernel = [1usize, 3][rng.range_usize(0, 1)];
        let pad = kernel / 2;
        let stride = rng.range_usize(1, 2);
        // Half the stages keep the channel count so residual links stay
        // shape-compatible; otherwise draw from the palette, filtered to
        // the block width when this stage is channel-blocked (the ragged
        // counts keep flowing through the unblocked layouts).
        let cout = if rng.bool() {
            c
        } else {
            let cb = if let Layout::Nchwc(cb) = layout { cb } else { 1 };
            let pool: Vec<usize> =
                CHANNELS.iter().copied().filter(|&cc| cc % cb == 0).collect();
            pool[rng.range_usize(0, pool.len() - 1)]
        };
        let wid = add_weight(&mut g, rng, format!("c{i}.w"), cout, c, kernel, layout);
        let conv = g
            .add(
                format!("c{i}"),
                Op::Conv2d { stride, padding: pad, layout },
                vec![cur, wid],
            )
            .unwrap();
        let mut t = conv;
        if rng.bool() {
            let b: Vec<f32> = (0..cout).map(|_| rng.normal() * 0.1).collect();
            let bid = g.add_const_f32(format!("c{i}.b"), vec![cout], b).unwrap();
            t = g
                .add(format!("c{i}.bias"), Op::BiasAdd { layout }, vec![t, bid])
                .unwrap();
        }
        // kernel 1 or 3 with pad = kernel/2 and stride 1 preserves the
        // spatial dims, so a same-channel stride-1 stage supports a
        // residual link back to its input.
        let res_ok = stride == 1 && cout == c;
        let pre_relu = rng.bool();
        if res_ok && pre_relu && rng.bool() {
            t = add_residual(&mut g, rng, format!("c{i}.addpre"), t, cur);
        }
        if rng.bool() {
            t = g.add(format!("c{i}.relu"), Op::Relu, vec![t]).unwrap();
        }
        if res_ok && !pre_relu && rng.bool() {
            t = add_residual(&mut g, rng, format!("c{i}.addpost"), t, cur);
        }
        cur = t;
        c = cout;
        image = dims_of(&g.node(conv).ty.shape, layout).unwrap().2;
        if rng.bool() && image >= 2 {
            cur = g
                .add(
                    format!("c{i}.pool"),
                    Op::MaxPool { window: 2, stride: 2, padding: 0, layout },
                    vec![cur],
                )
                .unwrap();
            image = dims_of(&g.node(cur).ty.shape, layout).unwrap().2;
        }
    }
    let gap = g
        .add("gap", Op::GlobalAvgPool { layout }, vec![cur])
        .unwrap();
    let classes = rng.range_usize(2, 6);
    let fw: Vec<f32> = (0..c * classes).map(|_| rng.normal() * 0.3).collect();
    let fwid = g.add_const_f32("fc.w", vec![c, classes], fw).unwrap();
    let mut out = g.add("fc", Op::Dense, vec![gap, fwid]).unwrap();
    if rng.bool() {
        out = g.add("fc.relu", Op::Relu, vec![out]).unwrap();
    }
    g.output = out;
    g.validate().unwrap();
    g
}

/// Half the corpus is quantize-realized — and only a *random subset* of
/// the anchors, so the executor sees genuinely mixed fp32/int8 graphs
/// (quantized chains feeding fp32 chains and vice versa).
fn maybe_quantize(g: &Graph, rng: &mut Rng64) -> Graph {
    if !rng.bool() {
        return g.clone();
    }
    let calib = calibrate_ir(g, rng.next_u64());
    let mut scales = calibrate_graph(g, &calib).unwrap();
    // HashMap iteration order is unseeded; decide per sorted key so the
    // chosen subset is a pure function of the case seed.
    let mut keys: Vec<NodeId> = scales.keys().copied().collect();
    keys.sort_unstable();
    for k in keys {
        if !rng.bool() {
            scales.remove(&k);
        }
    }
    QuantizeRealize { scales }.run(g).unwrap()
}

#[test]
fn fuzz_arena_matches_oracle_across_threads() {
    let threads = thread_counts();
    let mut fused_chains = 0usize;
    let mut residual_steps = 0usize;
    let mut packed_fused_steps = 0usize;
    let mut packed_qconv_steps = 0usize;
    let mut cast_nodes = 0usize;
    for case in 0..CASES {
        let mut rng = Rng64::seed_from_u64(BASE_SEED ^ case);
        let g = random_graph(&mut rng);
        let g = maybe_quantize(&g, &mut rng);
        cast_nodes += g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::LayoutTransform { .. }))
            .count();
        let x = calibrate_ir(&g, rng.next_u64());
        let want = evaluate(&g, &x)
            .unwrap_or_else(|e| panic!("case {case}: oracle failed: {e}"));
        for &t in &threads {
            // The unfused ablation is thread-independent; one width
            // suffices for it.
            for fuse in [true, false] {
                if !fuse && t != 1 {
                    continue;
                }
                let exec = ArenaExec::with_options(&g, fuse, t)
                    .unwrap_or_else(|e| panic!("case {case} t{t} fuse={fuse}: compile failed: {e}"));
                if fuse && t == 1 {
                    let cg = exec.compiled();
                    fused_chains += cg.fused_chains;
                    residual_steps +=
                        cg.steps.iter().filter(|s| s.op.has_residual()).count();
                    for s in &cg.steps {
                        let packed =
                            s.op.conv_layout().map_or(false, |l| l != Layout::Nchw);
                        let fused_epi =
                            s.op.epilogue().map_or(false, |e| !e.is_identity());
                        if packed && fused_epi {
                            packed_fused_steps += 1;
                        }
                        if packed
                            && matches!(
                                s.op,
                                tvmq::graph::compile::StepOp::QConv2d { .. }
                            )
                        {
                            packed_qconv_steps += 1;
                        }
                    }
                }
                let mut out = TensorData::zeros(want.dtype, want.shape.clone());
                exec.run_into(&x, &mut out)
                    .unwrap_or_else(|e| panic!("case {case} t{t} fuse={fuse}: run failed: {e}"));
                assert_eq!(
                    want, out,
                    "case {case} t{t} fuse={fuse}: arena diverged from the oracle"
                );
            }
        }
    }
    // The corpus must actually exercise the layout-complete fusion layer —
    // plenty of fused chains, two-input residual epilogues, fused
    // epilogues on NON-NCHW anchors, collapsed q→conv→dq chains in the
    // packed layouts, and mixed-layout graphs with explicit cast nodes.
    assert!(
        fused_chains >= CASES as usize,
        "corpus fused only {fused_chains} chains across {CASES} cases"
    );
    assert!(
        residual_steps >= 10,
        "corpus fused only {residual_steps} residual epilogues"
    );
    assert!(
        packed_fused_steps >= 20,
        "corpus fused only {packed_fused_steps} packed-layout epilogues"
    );
    assert!(
        packed_qconv_steps >= 10,
        "corpus collapsed only {packed_qconv_steps} packed quantized chains"
    );
    assert!(
        cast_nodes >= 20,
        "corpus carried only {cast_nodes} layout-cast nodes"
    );
}

/// One full fuzz pass under a NON-default `ScheduleOverrides`: dynamic
/// chunk-1 banding on every anchor class plus a stack-lane bound of 2,
/// which forces the packed q-conv chains with cb = 4 onto the arena-spill
/// lane-accumulator path.  Schedule knobs must never change a bit.
#[test]
fn fuzz_overridden_schedule_matches_oracle() {
    use tvmq::executor::{ArenaExec, Banding};
    use tvmq::graph::compile::{ScheduleOverrides, StepSched};

    let ovr = ScheduleOverrides {
        max_stack_lanes: 2,
        default_sched: StepSched {
            banding: Some(Banding::Dynamic { chunk: 1 }),
            max_bands: 0,
            micro: None,
        },
        ..ScheduleOverrides::default()
    };
    let mut spill_steps = 0usize;
    for case in 0..CASES {
        let mut rng = Rng64::seed_from_u64(BASE_SEED ^ case);
        let g = random_graph(&mut rng);
        let g = maybe_quantize(&g, &mut rng);
        let x = calibrate_ir(&g, rng.next_u64());
        let want = evaluate(&g, &x)
            .unwrap_or_else(|e| panic!("case {case}: oracle failed: {e}"));
        let exec = ArenaExec::with_schedule(&g, true, 4, &ovr)
            .unwrap_or_else(|e| panic!("case {case}: tuned compile failed: {e}"));
        spill_steps += exec
            .compiled()
            .steps
            .iter()
            .filter(|s| s.spill.is_some())
            .count();
        let mut out = TensorData::zeros(want.dtype, want.shape.clone());
        exec.run_into(&x, &mut out)
            .unwrap_or_else(|e| panic!("case {case}: tuned run failed: {e}"));
        assert_eq!(
            want, out,
            "case {case}: overridden schedule diverged from the oracle"
        );
    }
    // The lowered bound must actually have exercised the spill kernel:
    // the corpus's packed quantized chains with cb = 4 exceed the bound
    // of 2 (cb = 2 chains stay on the stack — both strategies run).
    assert!(
        spill_steps >= 1,
        "override pass never exercised the spill-accumulator path"
    );
}

/// The tentpole's oracle gate: the full 200-seed corpus again, with the
/// register-blocked int8 microkernels FORCED onto every anchor
/// (`default_sched.micro = Some(..)`), at threads 1 / 2 / 4.  Three tile
/// geometries are cycled across the corpus — the shipped default, a tiny
/// tile where every loop is tail, and an oversized tile that clamps on
/// every layer — so the ragged channel palette exercises k-tail, m-tail,
/// and n-tail in every layout, fused chains included.  Microkernels are
/// a pure reassociation of i32 adds, so the bit-for-bit oracle equality
/// must hold on every seed; on x86_64 hosts the dispatched ISA is
/// whatever the machine (or `TVMQ_MICRO_ISA`) provides, so CI runs this
/// under both the SIMD and the scalar paths.
#[test]
fn fuzz_forced_microkernel_matches_oracle() {
    use tvmq::graph::compile::{ScheduleOverrides, StepSched};
    use tvmq::graph::MicroKernel;

    let tiles = [
        MicroKernel { mr: 4, nr: 8, ku: 8 },
        MicroKernel { mr: 1, nr: 2, ku: 3 },
        MicroKernel { mr: 7, nr: 16, ku: 32 },
    ];
    let mut packed_steps = 0usize;
    for case in 0..CASES {
        let mut rng = Rng64::seed_from_u64(BASE_SEED ^ case);
        let g = random_graph(&mut rng);
        let g = maybe_quantize(&g, &mut rng);
        let x = calibrate_ir(&g, rng.next_u64());
        let want = evaluate(&g, &x)
            .unwrap_or_else(|e| panic!("case {case}: oracle failed: {e}"));
        let ovr = ScheduleOverrides {
            default_sched: StepSched {
                banding: None,
                max_bands: 0,
                micro: Some(tiles[case as usize % tiles.len()]),
            },
            ..ScheduleOverrides::default()
        };
        for t in [1usize, 2, 4] {
            let exec = ArenaExec::with_schedule(&g, true, t, &ovr)
                .unwrap_or_else(|e| panic!("case {case} t{t}: micro compile failed: {e}"));
            if t == 1 {
                packed_steps += exec
                    .compiled()
                    .steps
                    .iter()
                    .filter(|s| s.packed.is_some())
                    .count();
            }
            let mut out = TensorData::zeros(want.dtype, want.shape.clone());
            exec.run_into(&x, &mut out)
                .unwrap_or_else(|e| panic!("case {case} t{t}: micro run failed: {e}"));
            assert_eq!(
                want, out,
                "case {case} t{t}: forced microkernel diverged from the oracle"
            );
        }
    }
    // Only quantized anchors have an int8 const weight panel to pre-pack
    // (half the corpus, random anchor subsets) — but the forced override
    // must have actually reached the microkernels, not compiled around
    // them.
    assert!(
        packed_steps >= 50,
        "forced-micro corpus pre-packed only {packed_steps} weight panels"
    );
}

#[test]
fn fuzz_generator_is_deterministic() {
    // The CI seed set must mean the same graphs everywhere.
    for case in [0u64, 63, 199] {
        let mut a = Rng64::seed_from_u64(BASE_SEED ^ case);
        let mut b = Rng64::seed_from_u64(BASE_SEED ^ case);
        let ga = maybe_quantize(&random_graph(&mut a), &mut a);
        let gb = maybe_quantize(&random_graph(&mut b), &mut b);
        assert_eq!(ga.len(), gb.len());
        let xa = calibrate_ir(&ga, a.next_u64());
        let xb = calibrate_ir(&gb, b.next_u64());
        assert_eq!(xa, xb);
        assert_eq!(evaluate(&ga, &xa).unwrap(), evaluate(&gb, &xb).unwrap());
    }
}
