//! Tuner contracts (ISSUE 5): search determinism (same seed + budget →
//! same best config), oracle-rejection (a candidate producing wrong bits
//! is never accepted), records round-trip (save → load →
//! `NativeArenaFactory` builds the tuned engine bit-equal to the oracle),
//! and the packed lane-accumulator boundary — the real cb = 64 / 65 edge
//! of `MAX_FUSED_QCONV_CB` plus the small-lane equivalent driven through
//! the `max_stack_lanes` knob.

use tvmq::executor::{
    ArenaExec, Banding, EngineFactory, EngineKind, EngineSpec, Executor, LayoutTag,
};
use tvmq::graph::compile::{ScheduleOverrides, StepOp, StepSched, MAX_FUSED_QCONV_CB};
use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use tvmq::graph::{
    build_conv_net, build_resnet_ir, calibrate_ir, evaluate, Graph, Layout, NetSpec, Op,
    TensorTy,
};
use tvmq::tune::{
    tune_graph, tune_with_measurer, KnobSpace, Measure, Measurement, MeasureOpts, Measurer,
    RunMeta, SchedulePlan, TuneOptions, TuneRecords,
};
use tvmq::util::rng::Rng64;

fn quantized(g: &Graph) -> Graph {
    let calib = calibrate_ir(g, 1);
    let scales = calibrate_graph(g, &calib).unwrap();
    QuantizeRealize { scales }.run(g).unwrap()
}

// ---------------------------------------------------------------------------
// Determinism
// ---------------------------------------------------------------------------

/// A deterministic stand-in cost function: scoring is a pure function of
/// the plan identity, so two same-seed searches must retrace each other
/// exactly — no timing noise to hide driver nondeterminism behind.
struct FakeMeasure;

impl Measure for FakeMeasure {
    fn measure(&self, plan: &SchedulePlan) -> anyhow::Result<Measurement> {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in plan.describe().bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Ok(Measurement { ns_per_iter: (h % 1_000_000) as f64 + 1.0 })
    }
}

#[test]
fn same_seed_and_budget_yield_the_same_best_config() {
    let g = quantized(&build_resnet_ir(1, 8, 7).unwrap());
    let space = KnobSpace::for_graph(&g, 4).unwrap();
    let opts = TuneOptions { budget: 20, seed: 99, threads: 4, ..TuneOptions::default() };
    let a = tune_with_measurer(space.clone(), &FakeMeasure, &opts).unwrap();
    let b = tune_with_measurer(space, &FakeMeasure, &opts).unwrap();
    assert_eq!(a.best.plan.describe(), b.best.plan.describe());
    assert_eq!(a.best.ns_per_iter, b.best.ns_per_iter);
    let seq_a: Vec<String> = a.trials.iter().map(|t| t.plan.describe()).collect();
    let seq_b: Vec<String> = b.trials.iter().map(|t| t.plan.describe()).collect();
    assert_eq!(seq_a, seq_b, "same seed must measure the same candidate sequence");
    assert!(a.trials.len() <= opts.budget);
    assert_eq!(a.trials[0].plan.describe(), SchedulePlan::default_for(&a.space.classes).describe());
}

/// The register-tile knob is a live search dimension exactly where it can
/// matter: int8-weight anchors.  fp32 anchors never sample it (it would
/// be inert — no panel to pre-pack), quantized anchors do, and a sampled
/// plan carrying a tile survives `overrides()` into the compiler.
#[test]
fn knob_space_exposes_micro_dimension_only_for_int8_anchors() {
    let g = build_resnet_ir(1, 8, 7).unwrap();
    let qg = quantized(&g);
    let fp = KnobSpace::for_graph(&g, 2).unwrap();
    assert!(
        fp.micro_live.iter().all(|&live| !live),
        "fp32 anchors must not expose the register-tile knob"
    );
    let q = KnobSpace::for_graph(&qg, 2).unwrap();
    assert!(
        q.micro_live.iter().any(|&live| live),
        "quantized anchors must expose the register-tile knob"
    );
    let mut rng = Rng64::seed_from_u64(7);
    let plan = (0..64)
        .map(|_| q.sample(&mut rng))
        .find(|p| p.uses_micro())
        .expect("sampling the quantized space never chose a register tile");
    let ovr = plan.overrides(2);
    let tiled = ovr
        .per_class
        .values()
        .chain(ovr.per_shape.values())
        .any(|s| s.micro.is_some());
    assert!(tiled, "a sampled register tile must survive into ScheduleOverrides");
}

// ---------------------------------------------------------------------------
// Oracle rejection
// ---------------------------------------------------------------------------

#[test]
fn candidate_with_wrong_bits_is_rejected_not_timed() {
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let x = calibrate_ir(&g, 3);
    let mut oracle = evaluate(&g, &x).unwrap();
    // Flip one bit of the expected output: every candidate now "produces
    // wrong bits" relative to the oracle and must be refused.
    oracle.data[0] ^= 1;
    let m = Measurer::with_oracle(&g, x, oracle, 2, MeasureOpts { warmup: 0, iters: 1 });

    let space = KnobSpace::for_graph(&g, 2).unwrap();
    let default = SchedulePlan::default_for(&space.classes);
    let err = m.measure(&default).unwrap_err().to_string();
    assert!(err.contains("oracle mismatch"), "wrong rejection reason: {err}");

    // The driver refuses to search on a measurer whose baseline fails.
    let err = tune_with_measurer(space, &m, &TuneOptions::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("oracle"), "tune should surface the oracle failure: {err}");
}

#[test]
fn honest_measurer_accepts_every_schedule_knob() {
    // With the true oracle, candidates across the whole knob space are
    // accepted (schedule knobs are semantics-free) — and the search's
    // winner re-verifies against the interpreter.
    let g = quantized(&build_conv_net(&NetSpec::small(1)).unwrap());
    let x = calibrate_ir(&g, 5);
    let opts = TuneOptions {
        budget: 10,
        seed: 3,
        threads: 2,
        warmup: 0,
        iters: 2,
        use_prior: true,
    };
    let outcome = tune_graph(&g, x.clone(), &opts).unwrap();
    assert_eq!(outcome.rejected, 0, "no schedule knob may change a bit");
    assert!(outcome.trials.len() >= 2, "search must measure beyond the default");
    assert!(outcome.best.ns_per_iter <= outcome.default_ns);

    let best = &outcome.best.plan;
    let exec = ArenaExec::with_schedule(&g, best.fuse, 2, &best.overrides(2)).unwrap();
    assert_eq!(evaluate(&g, &x).unwrap(), exec.run(&x).unwrap());
}

// ---------------------------------------------------------------------------
// Records round-trip → tuned factory engine
// ---------------------------------------------------------------------------

#[test]
fn records_round_trip_and_factory_builds_the_tuned_engine() {
    let spec = EngineSpec::new(EngineKind::Arena).layout(LayoutTag::Nchw);
    let factory = tvmq::executor::NativeArenaFactory::new(spec, &[1, 2], 12, 1).unwrap();
    let g1 = factory.graph(1).unwrap();
    let g2 = factory.graph(2).unwrap();

    let outcome = tune_graph(
        &g1,
        calibrate_ir(&g1, 42),
        &TuneOptions { budget: 6, seed: 11, threads: 1, warmup: 0, iters: 2, use_prior: true },
    )
    .unwrap();
    let records = TuneRecords::from_outcome(
        &outcome,
        &RunMeta {
            model: "resnet10".into(),
            layout: "NCHW".into(),
            precision: "int8".into(),
            image: 12,
            batch: 1,
        },
    );
    assert!(!records.records.is_empty(), "resnet must expose tunable anchor classes");

    let path = std::env::temp_dir().join(format!("tvmq-tune-{}.json", std::process::id()));
    records.save(&path).unwrap();
    let loaded = TuneRecords::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(records, loaded, "records must survive save → load bit-exactly");

    // The loaded records drive the factory's tuned path; the bucket-2
    // engine (a batch the tune never saw — class-keyed transfer) must
    // still be bit-identical to the interpreter oracle.
    let tuned = factory.with_schedule(loaded.overrides(1), loaded.fuse);
    assert!(tuned.describe().contains("tuned"), "factory should advertise the tuned path");
    let engine = tuned.build(2).unwrap();
    let x = calibrate_ir(&g2, 8);
    assert_eq!(evaluate(&g2, &x).unwrap(), engine.run(&x).unwrap());

    // Acceptance: the records file loaded into an engine must stay
    // bit-for-bit equal to the oracle at threads 1 AND 4 (spill windows
    // and band counts re-sized for the wider pool).
    let x1 = calibrate_ir(&g1, 13);
    let want = evaluate(&g1, &x1).unwrap();
    for threads in [1usize, 4] {
        let exec =
            ArenaExec::with_schedule(&g1, loaded.fuse, threads, &loaded.overrides(threads))
                .unwrap();
        assert_eq!(want, exec.run(&x1).unwrap(), "t{threads}: tuned run diverged");
    }
}

// ---------------------------------------------------------------------------
// Packed lane-accumulator boundary
// ---------------------------------------------------------------------------

/// Minimal packed quantized chain: `x → quantize → conv(NCHW{cb}c, i8
/// weight) → dequantize`, 1×1 kernel so any `cb` stays tiny.
fn packed_qconv_graph(cb: usize, seed: u64) -> Graph {
    let mut g = Graph::new();
    let mut rng = Rng64::seed_from_u64(seed);
    let x = g.add_input("x", TensorTy::f32(vec![1, 1, 3, 3, cb]));
    let q = g.add("q", Op::Quantize { scale: 0.05 }, vec![x]).unwrap();
    let w: Vec<i8> = (0..cb * cb).map(|_| rng.i8()).collect();
    let wid = g.add_const_i8("w", vec![1, 1, 1, 1, cb, cb], w).unwrap();
    let conv = g
        .add(
            "conv",
            Op::Conv2d { stride: 1, padding: 0, layout: Layout::Nchwc(cb) },
            vec![q, wid],
        )
        .unwrap();
    g.output = g.add("dq", Op::Dequantize { scale: 0.1 }, vec![conv]).unwrap();
    g.validate().unwrap();
    g
}

fn fused_qconv_step(exec: &ArenaExec) -> &tvmq::graph::compile::Step {
    exec.compiled()
        .steps
        .iter()
        .find(|s| matches!(s.op, StepOp::QConv2d { .. }))
        .expect("chain should fuse into a QConv2d step")
}

#[test]
fn cb_64_fuses_on_the_stack_and_cb_65_fuses_through_spill() {
    // The real boundary of the fixed stack array: 64 stays stack-resident,
    // 65 — which used to silently stay unfused — now fuses with per-band
    // arena spill windows, and both match the oracle bit-for-bit.
    for (cb, want_spill) in [(MAX_FUSED_QCONV_CB, false), (MAX_FUSED_QCONV_CB + 1, true)] {
        let g = packed_qconv_graph(cb, 17);
        let x = calibrate_ir(&g, 2);
        let want = evaluate(&g, &x).unwrap();
        for threads in [1usize, 2] {
            let exec = ArenaExec::with_options(&g, true, threads).unwrap();
            assert_eq!(
                exec.compiled().fused_chains,
                1,
                "cb={cb}: the q→conv→dq chain must fuse"
            );
            let step = fused_qconv_step(&exec);
            assert_eq!(
                step.spill.is_some(),
                want_spill,
                "cb={cb}: wrong lane-accumulator strategy"
            );
            if let Some(sp) = step.spill {
                assert!(sp.bands >= threads, "spill windows must cover the pool");
                assert!(sp.band_bytes >= cb * 4);
            }
            assert_eq!(
                want,
                exec.run(&x).unwrap(),
                "cb={cb} t{threads}: packed fused conv diverged from the oracle"
            );
        }
    }
}

#[test]
fn stack_lane_knob_boundary_small_lane_equivalent() {
    // The same 64/65 edge exercised cheaply through the knob: with
    // max_stack_lanes = b, a cb = b block accumulates on the stack and a
    // cb > b block spills — both bit-exact, at 1 and 4 threads.
    let g = packed_qconv_graph(4, 23);
    let x = calibrate_ir(&g, 9);
    let want = evaluate(&g, &x).unwrap();
    for (lanes, want_spill) in [(4usize, false), (3, true), (2, true)] {
        let ovr = ScheduleOverrides { max_stack_lanes: lanes, ..ScheduleOverrides::default() };
        for threads in [1usize, 4] {
            let exec = ArenaExec::with_schedule(&g, true, threads, &ovr).unwrap();
            let step = fused_qconv_step(&exec);
            assert_eq!(
                step.spill.is_some(),
                want_spill,
                "lanes={lanes}: wrong strategy for cb=4"
            );
            assert_eq!(
                want,
                exec.run(&x).unwrap(),
                "lanes={lanes} t{threads}: spill/stack strategies must agree bitwise"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Banding overrides are inert on results (direct, non-fuzz pin)
// ---------------------------------------------------------------------------

#[test]
fn every_banding_override_is_bit_exact_on_a_residual_net() {
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let qg = quantized(&g);
    for graph in [&g, &qg] {
        let x = calibrate_ir(graph, 6);
        let want = evaluate(graph, &x).unwrap();
        for banding in [
            Banding::Contiguous,
            Banding::Interleaved,
            Banding::Dynamic { chunk: 1 },
            Banding::Dynamic { chunk: 3 },
        ] {
            for max_bands in [0usize, 1, 3] {
                let ovr = ScheduleOverrides {
                    default_sched: StepSched { banding: Some(banding), max_bands, micro: None },
                    ..ScheduleOverrides::default()
                };
                let exec = ArenaExec::with_schedule(graph, true, 4, &ovr).unwrap();
                assert_eq!(
                    want,
                    exec.run(&x).unwrap(),
                    "{banding:?}/b{max_bands}: schedule changed the result"
                );
            }
        }
    }
}
