//! Warm-start serving: a second `serve` over the same cache dir must
//! build every bucket engine with **zero** `graph::compile` calls while
//! still replying bit-identically to the interpreter oracle.
//!
//! This file holds exactly one test: `tvmq::graph::compile_calls()` is a
//! process-global counter, so sharing the binary with other tests would
//! make the zero-delta assertion racy.

use std::sync::Arc;

use tvmq::cache::CompileCache;
use tvmq::coordinator::{InferenceServer, ServeConfig};
use tvmq::executor::{EngineFactory, EngineKind, EngineSpec, NativeArenaFactory, Precision};
use tvmq::graph::{compile_calls, evaluate};
use tvmq::runtime::TensorData;
use tvmq::util::rng::Rng64;

const IMAGE: usize = 16;
const BUCKETS: [usize; 2] = [1, 2];

fn seeded_image(seed: u64) -> TensorData {
    let mut rng = Rng64::seed_from_u64(seed);
    let vals: Vec<f32> = (0..3 * IMAGE * IMAGE).map(|_| rng.normal() * 0.5).collect();
    TensorData::from_f32(vec![1, 3, IMAGE, IMAGE], &vals).unwrap()
}

#[test]
fn warm_start_serves_with_zero_compiles_and_oracle_exact_logits() {
    let dir = std::env::temp_dir().join(format!("tvmq-warmstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = EngineSpec::new(EngineKind::Arena).precision(Precision::Fp32);

    // Cold pass: compile every bucket once, populating the cache.
    let cache = Arc::new(CompileCache::open(&dir).unwrap());
    let cold = NativeArenaFactory::new(spec, &BUCKETS, IMAGE, 1)
        .unwrap()
        .with_cache(cache.clone());
    for &b in &BUCKETS {
        cold.build(b).unwrap();
    }
    let s = cache.stats();
    assert_eq!((s.misses, s.stores, s.hits), (2, 2, 0), "cold pass populates, never hits");

    // Warm pass: a fresh factory and a fresh (verifying) cache handle over
    // the same directory, serving through the full coordinator.
    let cache2 = Arc::new(CompileCache::open(&dir).unwrap().with_verify(true));
    let warm = NativeArenaFactory::new(spec, &BUCKETS, IMAGE, 1)
        .unwrap()
        .with_cache(cache2.clone());
    let oracle_graph = warm.graph(1).unwrap();

    let before = compile_calls();
    let server = InferenceServer::start_with(
        warm,
        ServeConfig { spec, max_batch: 2, ..ServeConfig::default() },
    )
    .unwrap();
    for seed in 0..4u64 {
        let img = seeded_image(seed);
        let reply = server.submit_blocking(img.clone()).unwrap();
        let want = evaluate(&oracle_graph, &img).unwrap();
        let got_bits: Vec<u32> =
            reply.logits.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> =
            want.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "warm-start reply diverged from the oracle");
    }
    let after = compile_calls();
    server.shutdown().unwrap();

    assert_eq!(
        after - before,
        0,
        "warm start must construct every bucket engine without invoking graph::compile"
    );
    let s = cache2.stats();
    assert_eq!(s.hits, BUCKETS.len() as u64, "every bucket must be a cache hit");
    assert_eq!((s.misses, s.rejected), (0, 0));
    let _ = std::fs::remove_dir_all(&dir);
}
