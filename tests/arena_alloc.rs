//! Zero-allocation property of the arena executor's serving path.
//!
//! This lives in its own integration-test binary so the counting global
//! allocator and its counter see no traffic from unrelated tests running
//! in sibling threads (the tests here serialize against each other via
//! `SERIAL`).  `ArenaExec::run_into` must perform **zero heap allocations
//! after warm-up** at every thread count: every intermediate lives at a
//! pre-planned arena offset, and at `threads > 1` the kernels fan out
//! over the executor's *persistent* worker pool — workers are spawned at
//! build time and each dispatch goes through a futex-backed mutex/condvar
//! slot, which allocates nothing.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use tvmq::executor::ArenaExec;
use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use tvmq::graph::{build_conv_net, calibrate_ir, Graph, NetSpec};
use tvmq::runtime::TensorData;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// The counting window is process-global, so the tests in this binary
/// must not overlap; each takes this lock for its whole body.
static SERIAL: Mutex<()> = Mutex::new(());

struct CountingAlloc;

// SAFETY: delegates straight to System; the counter has no side effects on
// allocation behaviour.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `exec` to a steady state, then assert 5 further inferences
/// allocate nothing and still produce finite output.
fn assert_zero_alloc_steady_state(exec: &ArenaExec, x: &TensorData, tag: &str) {
    let mut out = TensorData::zeros(
        tvmq::runtime::DType::F32,
        exec.compiled().output_ty.shape.clone(),
    );

    // Warm-up (first runs may fault in lazily-mapped arena pages; they must
    // not allocate either, but only the steady state is the contract).
    exec.run_into(x, &mut out).unwrap();
    exec.run_into(x, &mut out).unwrap();

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        exec.run_into(x, &mut out).unwrap();
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{tag}: ArenaExec::run_into allocated {} times across 5 inferences",
        after - before
    );

    // The result is still the real one (guards against dead-code tricks).
    assert!(out.as_f32_slice().unwrap().iter().all(|v| v.is_finite()));
}

fn quantized(g: &Graph) -> Graph {
    let calib = calibrate_ir(g, 1);
    let scales = calibrate_graph(g, &calib).unwrap();
    QuantizeRealize { scales }.run(g).unwrap()
}

#[test]
fn run_into_is_allocation_free_after_warmup() {
    let _serial = SERIAL.lock().unwrap();
    // Quantized graph: exercises the fused q→conv→dq path and scratch use.
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let qg = quantized(&g);

    let exec = ArenaExec::with_options(&qg, true, 1).unwrap();
    let x = calibrate_ir(&qg, 2);
    assert_zero_alloc_steady_state(&exec, &x, "int8 t1");
}

#[test]
fn run_into_is_allocation_free_with_worker_pool_and_fused_residual() {
    let _serial = SERIAL.lock().unwrap();
    let threads = std::env::var("TVMQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 2)
        .unwrap_or(4);

    // NetSpec::small has a same-channel stride-1 residual stage, so the
    // fp32 graph compiles conv+bias+relu chains *and* a two-input
    // residual-Add epilogue; the quantized twin fuses the same tail onto
    // its q→conv→dq chains.
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let qg = quantized(&g);

    for (tag, graph) in [("fp32", &g), ("int8", &qg)] {
        let exec = ArenaExec::with_options(graph, true, threads).unwrap();
        assert!(
            exec.compiled().steps.iter().any(|s| s.op.has_residual()),
            "{tag}: expected a fused residual-Add epilogue step"
        );
        assert!(
            exec.compiled().fused_chains > 0,
            "{tag}: expected fused chains"
        );
        let x = calibrate_ir(graph, 3);
        assert_zero_alloc_steady_state(&exec, &x, &format!("{tag} t{threads}"));
    }
}
