//! Zero-allocation property of the arena executor's serving path.
//!
//! This lives in its own integration-test binary so the counting global
//! allocator and its counter see no traffic from unrelated tests running
//! in sibling threads.  With `threads == 1` (scoped-thread fan-out
//! disabled — spawning itself allocates), `ArenaExec::run_into` must
//! perform **zero heap allocations after warm-up**: every intermediate
//! lives at a pre-planned arena offset.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tvmq::executor::ArenaExec;
use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use tvmq::graph::{build_conv_net, calibrate_ir, NetSpec};
use tvmq::runtime::TensorData;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates straight to System; the counter has no side effects on
// allocation behaviour.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn run_into_is_allocation_free_after_warmup() {
    // Quantized graph: exercises the fused q→conv→dq path and scratch use.
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let calib = calibrate_ir(&g, 1);
    let scales = calibrate_graph(&g, &calib).unwrap();
    let qg = QuantizeRealize { scales }.run(&g).unwrap();

    let exec = ArenaExec::with_options(&qg, true, 1).unwrap();
    let x = calibrate_ir(&qg, 2);
    let mut out = TensorData::zeros(
        tvmq::runtime::DType::F32,
        exec.compiled().output_ty.shape.clone(),
    );

    // Warm-up (first runs may fault in lazily-mapped arena pages; they must
    // not allocate either, but only the steady state is the contract).
    exec.run_into(&x, &mut out).unwrap();
    exec.run_into(&x, &mut out).unwrap();

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        exec.run_into(&x, &mut out).unwrap();
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "ArenaExec::run_into allocated {} times across 5 inferences",
        after - before
    );

    // The result is still the real one (guards against dead-code tricks).
    assert!(out.as_f32_slice().unwrap().iter().all(|v| v.is_finite()));
}
