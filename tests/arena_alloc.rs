//! Zero-allocation property of the arena executor's serving path.
//!
//! This lives in its own integration-test binary so the counting global
//! allocator and its counter see no traffic from unrelated tests running
//! in sibling threads (the tests here serialize against each other via
//! `SERIAL`).  `ArenaExec::run_into` must perform **zero heap allocations
//! after warm-up** at every thread count: every intermediate lives at a
//! pre-planned arena offset, and at `threads > 1` the kernels fan out
//! over the executor's *persistent* worker pool — workers are spawned at
//! build time and each dispatch goes through a futex-backed mutex/condvar
//! slot, which allocates nothing.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use tvmq::executor::{ArenaExec, EngineFactory, Executor, NativeArenaFactory};
use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
use tvmq::graph::{build_conv_net, build_resnet_ir_in, calibrate_ir, Graph, Layout, NetSpec};
use tvmq::runtime::TensorData;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// The counting window is process-global, so the tests in this binary
/// must not overlap; each takes this lock for its whole body.
static SERIAL: Mutex<()> = Mutex::new(());

struct CountingAlloc;

// SAFETY: delegates straight to System; the counter has no side effects on
// allocation behaviour.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `exec` to a steady state, then assert 5 further inferences
/// allocate nothing and still produce finite output.
fn assert_zero_alloc_steady_state(exec: &ArenaExec, x: &TensorData, tag: &str) {
    let mut out = TensorData::zeros(
        tvmq::runtime::DType::F32,
        exec.compiled().output_ty.shape.clone(),
    );

    // Warm-up (first runs may fault in lazily-mapped arena pages; they must
    // not allocate either, but only the steady state is the contract).
    exec.run_into(x, &mut out).unwrap();
    exec.run_into(x, &mut out).unwrap();

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        exec.run_into(x, &mut out).unwrap();
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{tag}: ArenaExec::run_into allocated {} times across 5 inferences",
        after - before
    );

    // The result is still the real one (guards against dead-code tricks).
    assert!(out.as_f32_slice().unwrap().iter().all(|v| v.is_finite()));
}

fn quantized(g: &Graph) -> Graph {
    let calib = calibrate_ir(g, 1);
    let scales = calibrate_graph(g, &calib).unwrap();
    QuantizeRealize { scales }.run(g).unwrap()
}

#[test]
fn run_into_is_allocation_free_after_warmup() {
    let _serial = SERIAL.lock().unwrap();
    // Quantized graph: exercises the fused q→conv→dq path and scratch use.
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let qg = quantized(&g);

    let exec = ArenaExec::with_options(&qg, true, 1).unwrap();
    let x = calibrate_ir(&qg, 2);
    assert_zero_alloc_steady_state(&exec, &x, "int8 t1");
}

#[test]
fn run_into_is_allocation_free_with_worker_pool_and_fused_residual() {
    let _serial = SERIAL.lock().unwrap();
    let threads = std::env::var("TVMQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 2)
        .unwrap_or(4);

    // NetSpec::small has a same-channel stride-1 residual stage, so the
    // fp32 graph compiles conv+bias+relu chains *and* a two-input
    // residual-Add epilogue; the quantized twin fuses the same tail onto
    // its q→conv→dq chains.
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let qg = quantized(&g);

    for (tag, graph) in [("fp32", &g), ("int8", &qg)] {
        let exec = ArenaExec::with_options(graph, true, threads).unwrap();
        assert!(
            exec.compiled().steps.iter().any(|s| s.op.has_residual()),
            "{tag}: expected a fused residual-Add epilogue step"
        );
        assert!(
            exec.compiled().fused_chains > 0,
            "{tag}: expected fused chains"
        );
        let x = calibrate_ir(graph, 3);
        assert_zero_alloc_steady_state(&exec, &x, &format!("{tag} t{threads}"));
    }
}

#[test]
fn run_into_is_allocation_free_for_fused_packed_int8() {
    let _serial = SERIAL.lock().unwrap();
    let threads = std::env::var("TVMQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 2)
        .unwrap_or(4);

    // A natively packed NCHW{8}c resnet, quantize-realized: the fused
    // packed q-conv kernel accumulates its i32 lanes in a stack array, so
    // the packed int8 tier must keep the zero-allocation contract at both
    // fan-outs (ISSUE 4 acceptance: threads 1 and 4).
    let g = build_resnet_ir_in(1, 12, 7, Layout::Nchwc(8)).unwrap();
    let qg = quantized(&g);
    for t in [1usize, threads] {
        let exec = ArenaExec::with_options(&qg, true, t).unwrap();
        assert!(
            exec.compiled().steps.iter().any(|s| {
                matches!(s.op.conv_layout(), Some(Layout::Nchwc(_)))
                    && s.op.epilogue().map_or(false, |e| !e.is_identity())
            }),
            "expected fused packed int8 epilogue steps"
        );
        let x = calibrate_ir(&qg, 2);
        assert_zero_alloc_steady_state(&exec, &x, &format!("int8 nchwc t{t}"));
    }
}

#[test]
fn run_into_is_allocation_free_with_forced_microkernels() {
    use tvmq::graph::compile::{ScheduleOverrides, StepSched};
    use tvmq::graph::MicroKernel;

    let _serial = SERIAL.lock().unwrap();

    // Register-blocked int8 microkernels with AOT pre-packed weights: the
    // packed panels were materialized at compile time next to the
    // constant pool and the dot tiles run over arena spans, so forcing
    // the microkernels onto every anchor must not add a single heap
    // allocation to the serving path — at threads 1 AND 4, including the
    // packed NCHW{c} tier (ISSUE 9 acceptance).
    let ovr = ScheduleOverrides {
        default_sched: StepSched {
            banding: None,
            max_bands: 0,
            micro: Some(MicroKernel::default()),
        },
        ..ScheduleOverrides::default()
    };
    for layout in [Layout::Nchw, Layout::Nchwc(8)] {
        let g = build_resnet_ir_in(1, 12, 7, layout).unwrap();
        let qg = quantized(&g);
        for t in [1usize, 4] {
            let exec = ArenaExec::with_schedule(&qg, true, t, &ovr).unwrap();
            assert!(
                exec.compiled().steps.iter().any(|s| s.packed.is_some()),
                "{layout:?}: forced micro never reached a pre-packed weight panel"
            );
            let x = calibrate_ir(&qg, 2);
            assert_zero_alloc_steady_state(&exec, &x, &format!("micro {layout:?} t{t}"));
        }
    }
}

#[test]
fn run_into_is_allocation_free_with_telemetry_profiling_attached() {
    use tvmq::telem::ProfileSink;

    let _serial = SERIAL.lock().unwrap();

    // Telemetry-on serving must not cost the zero-alloc contract: the
    // profiler's cells were interned at build time, `should_sample` is
    // one relaxed fetch_add per inference, and even a *sampled*
    // inference only reads clocks and bumps pre-allocated atomics.
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let qg = quantized(&g);
    for t in [1usize, 4] {
        // Sampling OFF on the measured inferences: with a huge period
        // only the very first inference (tick 0, during warm-up) is
        // sampled — the steady-state window runs the unsampled path.
        let mut exec = ArenaExec::with_options(&qg, true, t).unwrap();
        let sink = ProfileSink::new();
        exec.set_profiling(1_000_000, &sink);
        let x = calibrate_ir(&qg, 2);
        assert_zero_alloc_steady_state(&exec, &x, &format!("profiled-off int8 t{t}"));

        // Sampling ON for every inference: the sampled path itself is
        // also allocation-free (clock reads + relaxed atomic adds).
        let mut exec = ArenaExec::with_options(&qg, true, t).unwrap();
        let sink = ProfileSink::new();
        exec.set_profiling(1, &sink);
        assert_zero_alloc_steady_state(&exec, &x, &format!("profiled-on int8 t{t}"));
        let rows = sink.rows();
        assert!(!rows.is_empty(), "sampled inferences recorded no steps");
        assert!(rows.iter().all(|r| r.hits > 0), "every step was sampled 7 times");
        assert!(
            rows.iter().map(|r| r.total_ns).sum::<u64>() > 0,
            "profile rows must carry real timings: {rows:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// Serve loop: the executor path stays allocation-free end-to-end
// ---------------------------------------------------------------------------

/// Wraps an engine and records the allocation-counter delta across every
/// `run_into` call.  While the coordinator worker is inside `run_into`
/// the (single) client below is parked in `recv`, so the delta isolates
/// the executor path of the serve loop.
struct CountingExec {
    inner: Box<dyn Executor>,
    deltas: Arc<Mutex<Vec<u64>>>,
}

impl Executor for CountingExec {
    fn run(&self, input: &TensorData) -> anyhow::Result<TensorData> {
        self.inner.run(input)
    }

    fn run_into(&self, input: &TensorData, out: &mut TensorData) -> anyhow::Result<()> {
        let before = ALLOCS.load(Ordering::SeqCst);
        let r = self.inner.run_into(input, out);
        let after = ALLOCS.load(Ordering::SeqCst);
        // The Vec was pre-reserved: within capacity, push allocates
        // nothing, and it runs after the measurement window anyway.
        self.deltas.lock().unwrap().push(after - before);
        r
    }

    fn name(&self) -> &str {
        self.inner.name()
    }

    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn input_desc(&self) -> (Vec<usize>, tvmq::runtime::DType) {
        self.inner.input_desc()
    }

    fn output_desc(&self) -> (Vec<usize>, tvmq::runtime::DType) {
        self.inner.output_desc()
    }

    fn counters(&self) -> tvmq::executor::ExecSnapshot {
        self.inner.counters()
    }
}

struct CountingFactory {
    inner: NativeArenaFactory,
    deltas: Arc<Mutex<Vec<u64>>>,
}

impl EngineFactory for CountingFactory {
    fn buckets(&self) -> Vec<usize> {
        self.inner.buckets()
    }

    fn build(&self, batch: usize) -> anyhow::Result<Box<dyn Executor>> {
        Ok(Box::new(CountingExec {
            inner: self.inner.build(batch)?,
            deltas: self.deltas.clone(),
        }))
    }
}

#[test]
fn serve_loop_executor_path_is_allocation_free_in_steady_state() {
    use std::time::Duration;
    use tvmq::coordinator::{InferenceServer, ServeConfig};
    use tvmq::executor::{EngineKind, EngineSpec};
    use tvmq::util::rng::Rng64;

    let _serial = SERIAL.lock().unwrap();

    let spec = EngineSpec::new(EngineKind::Arena);
    let deltas = Arc::new(Mutex::new(Vec::with_capacity(64)));
    let factory = CountingFactory {
        inner: NativeArenaFactory::new(spec, &[1, 2], 12, 1).unwrap(),
        deltas: deltas.clone(),
    };
    let server = InferenceServer::start_with(
        factory,
        ServeConfig {
            spec,
            max_batch: 2,
            batch_timeout: Duration::from_millis(1),
            ..ServeConfig::default()
        },
    )
    .unwrap();

    let image = {
        let mut rng = Rng64::seed_from_u64(11);
        let vals: Vec<f32> = (0..3 * 12 * 12).map(|_| rng.normal() * 0.5).collect();
        TensorData::from_f32(vec![1, 3, 12, 12], &vals).unwrap()
    };

    // Warm-up: lazily mapped arena pages, channel internals, stats.
    for _ in 0..3 {
        server.submit_blocking(image.clone()).unwrap();
    }
    let warm = deltas.lock().unwrap().len();

    for _ in 0..5 {
        let reply = server.submit_blocking(image.clone()).unwrap();
        assert!(reply.logits.as_f32_slice().unwrap().iter().all(|v| v.is_finite()));
    }

    let deltas = deltas.lock().unwrap();
    assert_eq!(deltas.len(), warm + 5);
    assert_eq!(
        &deltas[warm..],
        &[0, 0, 0, 0, 0],
        "steady-state serving allocated inside the executor path"
    );
    drop(deltas);
    server.shutdown().unwrap();
}

/// The sharded tier keeps the contract: with 2 workers each owning its
/// own engine set, steady-state serving still performs zero allocations
/// inside `run_into` — replication multiplies engines, not per-request
/// heap traffic.
#[test]
fn sharded_serve_loop_executor_path_is_allocation_free_in_steady_state() {
    use std::time::Duration;
    use tvmq::coordinator::{InferenceServer, ServeConfig};
    use tvmq::executor::{EngineKind, EngineSpec};
    use tvmq::util::rng::Rng64;

    let _serial = SERIAL.lock().unwrap();

    let spec = EngineSpec::new(EngineKind::Arena);
    let deltas = Arc::new(Mutex::new(Vec::with_capacity(128)));
    let factory = CountingFactory {
        inner: NativeArenaFactory::new(spec, &[1, 2], 12, 1).unwrap(),
        deltas: deltas.clone(),
    };
    let server = Arc::new(
        InferenceServer::start_with(
            factory,
            ServeConfig {
                spec,
                max_batch: 2,
                batch_timeout: Duration::from_millis(1),
                workers: 2,
                ..ServeConfig::default()
            },
        )
        .unwrap(),
    );
    assert_eq!(server.workers(), 2);

    let image = {
        let mut rng = Rng64::seed_from_u64(13);
        let vals: Vec<f32> = (0..3 * 12 * 12).map(|_| rng.normal() * 0.5).collect();
        TensorData::from_f32(vec![1, 3, 12, 12], &vals).unwrap()
    };

    // Concurrent warm-up: enough parallel clients that both workers pop
    // work and fault in their arenas (the run_into deltas themselves
    // should be zero even cold — the arena preallocates at build — but
    // only the steady state is the contract).
    let warmers: Vec<_> = (0..4)
        .map(|_| {
            let server = Arc::clone(&server);
            let image = image.clone();
            std::thread::spawn(move || {
                for _ in 0..3 {
                    server.submit_blocking(image.clone()).unwrap();
                }
            })
        })
        .collect();
    for w in warmers {
        w.join().unwrap();
    }
    let warm = deltas.lock().unwrap().len();

    // Measured phase: serial, so every delta window is quiet.
    for _ in 0..6 {
        let reply = server.submit_blocking(image.clone()).unwrap();
        assert!(reply.logits.as_f32_slice().unwrap().iter().all(|v| v.is_finite()));
    }

    let tail: Vec<u64> = deltas.lock().unwrap()[warm..].to_vec();
    assert_eq!(tail.len(), 6);
    assert!(
        tail.iter().all(|&d| d == 0),
        "sharded steady-state serving allocated inside the executor path: {tail:?}"
    );
    let stats = server.stats();
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.requests, 12 + 6);
    Arc::try_unwrap(server).ok().expect("clients joined").shutdown().unwrap();
}
