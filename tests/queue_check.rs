//! Exhaustive interleaving verification of the sharded coordinator's
//! admission-queue protocol — the multi-worker topologies the tentpole's
//! synchronization must survive.
//!
//! Each test hands `tvmq::check::check_queue` a small producers ×
//! consumers × items × bound configuration; the checker runs the **real**
//! `q_push`/`q_pop`/`q_shutdown`/`q_await_settled` code under the
//! deterministic scheduler and explores every schedule within the stated
//! preemption bound.  The validated property is settled-exactly-once:
//! every offered item is accepted-and-consumed once or shed once — which
//! is simultaneously dispatch fairness (no duplication, no starvation),
//! bounded depth, and no-lost-wakeup termination.  See the
//! `tvmq::check` module docs for exactly what a `complete` report does
//! and does not prove.
//!
//! Environment knobs (CI sets all three):
//! - `TVMQ_CHECK_BUDGET` — max schedules per scenario (default 200000);
//!   a truncated scenario FAILS its test.
//! - `TVMQ_CHECK_PREEMPTIONS` — preemption bound for the larger
//!   scenarios (default 1; the smallest always run at 2).
//! - `TVMQ_CHECK_SUMMARY` — JSONL path appended with one line per
//!   scenario (uploaded as a CI artifact).

use tvmq::check::{
    check_queue, check_queue_with, Explorer, QueueCheckConfig, QueueReport, SabotageBug,
};

fn budget() -> usize {
    std::env::var("TVMQ_CHECK_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000)
}

fn big_config_preemptions() -> usize {
    std::env::var("TVMQ_CHECK_PREEMPTIONS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

fn explorer(preemptions: usize) -> Explorer {
    Explorer { max_schedules: budget(), max_decisions: 10_000, preemptions }
}

/// Append one JSONL record of what a scenario explored (CI artifact).
fn record_summary(scenario: &str, cfg: &QueueCheckConfig, preemptions: usize, r: &QueueReport) {
    let Some(path) = std::env::var_os("TVMQ_CHECK_SUMMARY") else {
        return;
    };
    use std::io::Write;
    let line = format!(
        "{{\"scenario\":\"{scenario}\",\"producers\":{},\"consumers\":{},\
         \"items_per_producer\":{},\"bound\":{},\"preemptions\":{preemptions},\
         \"schedules\":{},\"complete\":{},\"peak_decisions\":{},\
         \"shed_total\":{},\"popped_total\":{}}}\n",
        cfg.producers,
        cfg.consumers,
        cfg.items_per_producer,
        cfg.bound,
        r.report.schedules,
        r.report.complete,
        r.report.peak_decisions,
        r.shed_total,
        r.popped_total
    );
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = f.write_all(line.as_bytes());
    }
}

/// Check `cfg` exhaustively at `preemptions`; fail on any convicted
/// schedule AND on budget truncation (incomplete exploration is not a
/// pass).
fn prove(scenario: &str, cfg: QueueCheckConfig, preemptions: usize) -> QueueReport {
    let r = check_queue(cfg, explorer(preemptions))
        .unwrap_or_else(|f| panic!("{scenario}: {f}"));
    record_summary(scenario, &cfg, preemptions, &r);
    assert!(
        r.report.complete,
        "{scenario}: exploration truncated at {} schedules — raise TVMQ_CHECK_BUDGET",
        r.report.schedules
    );
    r
}

fn cfg(producers: usize, consumers: usize, items: usize, bound: usize) -> QueueCheckConfig {
    QueueCheckConfig {
        producers,
        consumers,
        items_per_producer: items,
        bound,
        dead_consumer: None,
    }
}

/// Dispatch fairness across a multi-worker topology: one producer's
/// items through two consuming workers, queue roomy enough that nothing
/// sheds — every item must reach exactly one worker, under every
/// schedule at preemption bound 2.
#[test]
fn two_workers_dispatch_each_item_exactly_once() {
    let r = prove("queue-fair-1p2c", cfg(1, 2, 3, 3), 2);
    assert!(
        r.report.schedules >= 2,
        "scheduler never branched over {} schedules",
        r.report.schedules
    );
    assert_eq!(r.shed_total, 0, "a bound-3 queue offered 3 items must never shed");
    assert!(r.popped_total > 0);
}

/// Shed-under-burst: two producers racing two items each into a bound-1
/// queue with one consumer.  Every schedule settles every item exactly
/// once (accepted xor shed), and at least some schedules actually shed —
/// otherwise the admission gate was never exercised.
#[test]
fn burst_into_tiny_bound_sheds_cleanly() {
    let r = prove("queue-shed-burst", cfg(2, 1, 2, 1), 1);
    assert!(
        r.shed_total > 0,
        "a 4-item burst into a bound-1 queue must shed on some schedule"
    );
    assert!(r.popped_total > 0, "and still serve on some schedule");
}

/// Worker-death failover: consumer 0 exits after its first pop; the
/// surviving consumer must drain every remaining accepted item — no
/// stranded work, no lost wakeups, under every schedule.
#[test]
fn dead_consumer_strands_nothing() {
    let r = prove(
        "queue-death-failover",
        QueueCheckConfig {
            producers: 1,
            consumers: 2,
            items_per_producer: 3,
            bound: 2,
            dead_consumer: Some(0),
        },
        big_config_preemptions(),
    );
    assert!(r.popped_total > 0);
}

/// The checker's own oracle: a deliberately lost push wakeup (a consumer
/// asleep through an item's arrival) must be convicted as a deadlock.
/// A green checker that cannot find this bug proves nothing.
#[test]
fn checker_convicts_a_lost_push_wakeup() {
    let f = check_queue_with(cfg(1, 1, 1, 1), explorer(1), Some(SabotageBug::DropFirstWorkWake))
        .expect_err("a dropped push wakeup must be detected");
    assert!(
        f.description.contains("deadlock"),
        "expected a deadlock conviction, got: {f}"
    );
    assert!(!f.schedule.is_empty(), "conviction must carry the failing schedule");
}

/// Same oracle for the settle side: losing the done-wake that releases
/// the closer's settle-wait must be convicted.
#[test]
fn checker_convicts_a_lost_settle_wakeup() {
    let f = check_queue_with(cfg(1, 1, 1, 1), explorer(1), Some(SabotageBug::DropDoneWake))
        .expect_err("a dropped settle wakeup must be detected");
    assert!(
        f.description.contains("deadlock"),
        "expected a deadlock conviction, got: {f}"
    );
}
