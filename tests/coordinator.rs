//! Batcher behavior, pinned with a mock engine factory — no artifacts, no
//! PJRT, no real model.  The mock executor records every batch it serves
//! and computes logits from the input rows, so the tests can verify
//! gather/timeout/padding/truncate behavior *and* that each reply carries
//! the right row (class), bucket, and shape.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};
use tvmq::coordinator::{InferenceServer, PendingReply, ServeConfig};
use tvmq::executor::{
    EngineFactory, EngineKind, EngineSpec, ExecSnapshot, Executor,
};
use tvmq::runtime::{DType, TensorData};

const DIM: usize = 4;
const CLASSES: usize = 8;

/// Deterministic stand-in engine: input `[batch, DIM]`, output
/// `[batch, CLASSES]`, where row `i`'s logits peak at index
/// `round(input[i][0])` — so the expected class is encoded in the image
/// and a reply routed to the wrong request is caught immediately.
struct MockExec {
    batch: usize,
    /// Bucket sizes actually served, in order (shared with the factory).
    calls: Arc<Mutex<Vec<usize>>>,
    fail: bool,
}

impl Executor for MockExec {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        if self.fail {
            return Err(anyhow!("mock engine failure"));
        }
        if input.shape != vec![self.batch, DIM] {
            return Err(anyhow!("mock: bad input shape {:?}", input.shape));
        }
        self.calls.lock().unwrap().push(self.batch);
        let x = input.as_f32_slice()?;
        let mut out = vec![0f32; self.batch * CLASSES];
        for i in 0..self.batch {
            let v = x[i * DIM];
            for j in 0..CLASSES {
                out[i * CLASSES + j] = -((j as f32) - v).abs();
            }
        }
        TensorData::from_f32(vec![self.batch, CLASSES], &out)
    }

    fn name(&self) -> &str {
        "mock"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        (vec![self.batch, DIM], DType::F32)
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        (vec![self.batch, CLASSES], DType::F32)
    }

    fn counters(&self) -> ExecSnapshot {
        ExecSnapshot {
            invocations: 0,
            dispatches: 0,
            dynamic_allocs: 0,
            boundary_bytes: 0,
            instructions: 0,
        }
    }
}

struct MockFactory {
    buckets: Vec<usize>,
    calls: Arc<Mutex<Vec<usize>>>,
    fail: bool,
}

impl MockFactory {
    fn new(buckets: &[usize]) -> Self {
        MockFactory {
            buckets: buckets.to_vec(),
            calls: Arc::new(Mutex::new(Vec::new())),
            fail: false,
        }
    }
}

impl EngineFactory for MockFactory {
    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        Ok(Box::new(MockExec { batch, calls: self.calls.clone(), fail: self.fail }))
    }
}

/// An image whose expected class is `class`.
fn image(class: usize) -> TensorData {
    TensorData::from_f32(vec![1, DIM], &[class as f32; DIM]).unwrap()
}

fn cfg(max_batch: usize, timeout_ms: u64) -> ServeConfig {
    ServeConfig {
        spec: EngineSpec::new(EngineKind::Arena),
        max_batch,
        batch_timeout: Duration::from_millis(timeout_ms),
        ..ServeConfig::default()
    }
}

#[test]
fn partial_batch_pads_to_the_next_bucket_and_truncates_replies() {
    let factory = MockFactory::new(&[1, 2, 4]);
    let calls = factory.calls.clone();
    // Generous timeout: the three requests below must land in ONE batch.
    let server = InferenceServer::start_with(factory, cfg(4, 200)).unwrap();

    let pending: Vec<PendingReply> =
        (0..3).map(|c| server.submit(image(c)).unwrap()).collect();
    for (c, p) in pending.into_iter().enumerate() {
        let reply = p.wait().unwrap();
        // Gathered 3 → smallest fitting bucket is 4 (padded by one slot).
        assert_eq!(reply.batch, 4);
        // Row `c`'s logits, not a padding row and not a neighbor's.
        assert_eq!(reply.logits.shape, vec![1, CLASSES]);
        assert_eq!(reply.class, c);
        let want: Vec<f32> =
            (0..CLASSES).map(|j| -((j as f32) - c as f32).abs()).collect();
        assert_eq!(reply.logits.as_f32().unwrap(), want);
    }

    let stats = server.stats();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.padded_slots, 1);
    assert_eq!(stats.batch_histogram.get(&4), Some(&1));
    // The mean-batch regression: 3 requests in one (padded) batch must
    // report 3.0, not the bucket size the old histogram average gave.
    assert!((stats.mean_batch() - 3.0).abs() < 1e-12, "got {}", stats.mean_batch());
    // And the gathered histogram keys on the actual pre-padding size.
    assert_eq!(stats.gathered_histogram.get(&3), Some(&1));
    assert_eq!(stats.gathered_histogram.get(&4), None);
    assert_eq!(*calls.lock().unwrap(), vec![4]);
    server.shutdown().unwrap();
}

#[test]
fn sequential_requests_serve_in_the_smallest_bucket() {
    let factory = MockFactory::new(&[1, 2, 4]);
    let calls = factory.calls.clone();
    let server = InferenceServer::start_with(factory, cfg(4, 1)).unwrap();

    for c in 0..3 {
        let reply = server.submit_blocking(image(c)).unwrap();
        assert_eq!(reply.batch, 1, "a lone request must not be over-padded");
        assert_eq!(reply.class, c);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.batches, 3);
    assert_eq!(stats.padded_slots, 0);
    assert_eq!(stats.batch_histogram.get(&1), Some(&3));
    assert_eq!(*calls.lock().unwrap(), vec![1, 1, 1]);
    server.shutdown().unwrap();
}

#[test]
fn gather_is_capped_by_max_batch() {
    let factory = MockFactory::new(&[1, 2, 4]);
    let calls = factory.calls.clone();
    // max_batch 2 < largest bucket: batches must flush at 2 even though a
    // 4-engine exists.
    let server = InferenceServer::start_with(factory, cfg(2, 500)).unwrap();

    let pending: Vec<PendingReply> =
        (0..4).map(|c| server.submit(image(c)).unwrap()).collect();
    for p in pending {
        let reply = p.wait().unwrap();
        assert!(reply.batch <= 2, "batch {} exceeds max_batch", reply.batch);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 4);
    assert!(calls.lock().unwrap().iter().all(|&b| b <= 2));
    server.shutdown().unwrap();
}

#[test]
fn engine_failure_fails_every_job_in_the_batch_and_counts() {
    let mut factory = MockFactory::new(&[1, 2]);
    factory.fail = true;
    let server = InferenceServer::start_with(factory, cfg(2, 100)).unwrap();

    let pending: Vec<PendingReply> =
        (0..2).map(|c| server.submit(image(c)).unwrap()).collect();
    for p in pending {
        let err = p.wait().unwrap_err().to_string();
        assert!(err.contains("mock engine failure"), "got: {err}");
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 0);
    assert_eq!(stats.errors, 2);
    server.shutdown().unwrap();
}

#[test]
fn mismatched_image_is_rejected_not_served() {
    let factory = MockFactory::new(&[1]);
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();
    let bad = TensorData::from_f32(vec![1, DIM + 1], &[0.0; DIM + 1]).unwrap();
    let err = server.submit_blocking(bad).unwrap_err().to_string();
    assert!(err.contains("does not fit"), "got: {err}");
    let stats = server.stats();
    assert_eq!(stats.requests, 0);
    assert_eq!(stats.errors, 1);
    server.shutdown().unwrap();
}

/// The blast-radius regression: one malformed image co-gathered with two
/// valid requests must fail alone — the innocents are still served, with
/// the right rows.
#[test]
fn malformed_image_fails_only_its_own_job() {
    let factory = MockFactory::new(&[1, 2, 4]);
    let calls = factory.calls.clone();
    // Generous timeout so all three land in one gather.
    let server = InferenceServer::start_with(factory, cfg(4, 200)).unwrap();

    let good_a = server.submit(image(1)).unwrap();
    let bad = server
        .submit(TensorData::from_f32(vec![1, DIM + 1], &[9.0; DIM + 1]).unwrap())
        .unwrap();
    let good_b = server.submit(image(2)).unwrap();

    let err = bad.wait_timeout(Duration::from_secs(10)).unwrap_err().to_string();
    assert!(err.contains("does not fit"), "got: {err}");
    let a = good_a.wait_timeout(Duration::from_secs(10)).unwrap();
    let b = good_b.wait_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!((a.class, b.class), (1, 2), "valid jobs must serve, correctly routed");
    // The two survivors fit bucket 2 after the invalid job was peeled off.
    assert_eq!(a.batch, 2);

    let stats = server.stats();
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.gathered_histogram.get(&2), Some(&1));
    assert_eq!(*calls.lock().unwrap(), vec![2]);
    server.shutdown().unwrap();
}

/// The class-only submit path: same answer, no logits payload.
#[test]
fn submit_class_replies_with_argmax_only() {
    let factory = MockFactory::new(&[1, 2]);
    let server = InferenceServer::start_with(factory, cfg(2, 1)).unwrap();
    for c in 0..3 {
        let reply = server.submit_class(image(c)).unwrap().wait().unwrap();
        assert_eq!(reply.class, c);
        assert_eq!(reply.batch, 1);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 0);
    server.shutdown().unwrap();
}

/// Sharded serving: N workers over one queue, every reply still routed to
/// the right request with the right logits, regardless of which worker's
/// engine set served it.
#[test]
fn multi_worker_server_serves_concurrent_clients_correctly() {
    let factory = MockFactory::new(&[1, 2, 4]);
    let server = Arc::new(
        InferenceServer::start_with(
            factory,
            ServeConfig { workers: 3, ..cfg(4, 2) },
        )
        .unwrap(),
    );
    assert_eq!(server.workers(), 3);
    assert_eq!(server.alive_workers(), 3);

    let mut clients = Vec::new();
    for t in 0..4 {
        let server = Arc::clone(&server);
        clients.push(std::thread::spawn(move || {
            for i in 0..8 {
                let c = (t * 8 + i) % CLASSES;
                let reply = server.submit_blocking(image(c)).unwrap();
                assert_eq!(reply.class, c, "reply routed to the wrong request");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 32);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.shed, 0, "32 blocking clients cannot fill a 1024 queue");
    assert_eq!(server.alive_workers(), 3);
    Arc::try_unwrap(server).ok().expect("clients joined").shutdown().unwrap();
}

#[test]
fn empty_factory_fails_startup() {
    let factory = MockFactory::new(&[]);
    assert!(InferenceServer::start_with(factory, cfg(4, 1)).is_err());
}

/// Shutdown with in-flight requests: everything accepted before
/// `request_shutdown` resolves (bounded by `wait_timeout`, so a lost
/// reply fails the assert instead of hanging the suite), and submissions
/// after it fail promptly instead of returning a reply that would block
/// forever.
#[test]
fn request_shutdown_rejects_new_submits_and_drains_queued_work() {
    let factory = MockFactory::new(&[1, 2, 4]);
    let server = InferenceServer::start_with(factory, cfg(4, 50)).unwrap();

    let pending: Vec<PendingReply> =
        (0..3).map(|c| server.submit(image(c)).unwrap()).collect();
    server.request_shutdown();

    let err = server.submit(image(5)).unwrap_err();
    assert!(err.to_string().contains("down"), "got: {err}");

    for (c, p) in pending.into_iter().enumerate() {
        let reply = p
            .wait_timeout(Duration::from_secs(10))
            .unwrap_or_else(|e| panic!("queued request {c} hung across shutdown: {e}"));
        assert_eq!(reply.class, c, "reply routed to the wrong request");
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 0);
    server.shutdown().unwrap();
}
