//! Memory planner invariants + layout packing properties (randomized,
//! seeded — the offline build's proptest substitute).

use tvmq::layout::{
    nchw_to_nhwc, nhwc_to_nchw, pack_nchwc, pack_oihw, unpack_nchwc, Nchw,
};
use tvmq::memplan::{StaticPlan, ValueLife};
use tvmq::util::rng::Rng64;

fn random_lives(rng: &mut Rng64, n: usize) -> Vec<ValueLife> {
    (0..n)
        .map(|i| {
            let def = rng.range_usize(0, 20);
            ValueLife {
                name: format!("v{i}"),
                bytes: rng.range_usize(1, 4096),
                def_step: def,
                last_use_step: def + rng.range_usize(0, 10),
            }
        })
        .collect()
}

#[test]
fn prop_first_fit_never_overlaps() {
    let mut rng = Rng64::seed_from_u64(5);
    for _ in 0..100 {
        let n = rng.range_usize(1, 24);
        let lives = random_lives(&mut rng, n);
        let plan = StaticPlan::first_fit(&lives);
        plan.verify().expect("planner produced overlapping placements");
        assert!(plan.arena_bytes <= plan.unshared_bytes);
        assert!(plan.reuse_factor() >= 1.0);
    }
}

#[test]
fn disjoint_lifetimes_share_space() {
    let lives = vec![
        ValueLife { name: "a".into(), bytes: 100, def_step: 0, last_use_step: 1 },
        ValueLife { name: "b".into(), bytes: 100, def_step: 2, last_use_step: 3 },
        ValueLife { name: "c".into(), bytes: 100, def_step: 4, last_use_step: 5 },
    ];
    let plan = StaticPlan::first_fit(&lives);
    assert_eq!(plan.arena_bytes, 100, "fully disjoint values must share one slot");
    assert_eq!(plan.unshared_bytes, 300);
}

#[test]
fn overlapping_lifetimes_get_distinct_space() {
    let lives = vec![
        ValueLife { name: "a".into(), bytes: 64, def_step: 0, last_use_step: 5 },
        ValueLife { name: "b".into(), bytes: 64, def_step: 1, last_use_step: 4 },
        ValueLife { name: "c".into(), bytes: 64, def_step: 2, last_use_step: 3 },
    ];
    let plan = StaticPlan::first_fit(&lives);
    assert_eq!(plan.arena_bytes, 192, "all live at step 2-3: no sharing possible");
    plan.verify().unwrap();
}

#[test]
fn residual_lifetime_extension_forces_disjoint_slots() {
    // The two-input epilogue scenario: a fused step at step 2 writes `dst`
    // while reading residual `r` elementwise.  If `r`'s life ends at its
    // last *graph* use (step 1, where the pre-fusion Add consumed it), the
    // planner is free to alias the two — exactly the hazard:
    let r_short = ValueLife { name: "r".into(), bytes: 128, def_step: 0, last_use_step: 1 };
    let dst = ValueLife { name: "dst".into(), bytes: 128, def_step: 2, last_use_step: 3 };
    let hazard = StaticPlan::first_fit(&[r_short.clone(), dst.clone()]);
    assert_eq!(
        hazard.space_disjoint("r", "dst"),
        Some(false),
        "without the extension the planner reuses r's slot for dst"
    );

    // The compiler extends every step source through its consuming step —
    // including the residual — which makes aliasing impossible.
    let mut r = r_short;
    r.extend_through(2);
    assert_eq!(r.last_use_step, 2);
    r.extend_through(1); // never shrinks
    assert_eq!(r.last_use_step, 2);
    let plan = StaticPlan::first_fit(&[r, dst]);
    plan.verify().unwrap();
    assert_eq!(plan.space_disjoint("r", "dst"), Some(true));
    assert_eq!(plan.space_disjoint("r", "nope"), None);
}

#[test]
fn verify_catches_bad_plan() {
    let mut plan = StaticPlan::first_fit(&[
        ValueLife { name: "a".into(), bytes: 10, def_step: 0, last_use_step: 2 },
        ValueLife { name: "b".into(), bytes: 10, def_step: 1, last_use_step: 3 },
    ]);
    // Sabotage: force overlap.
    plan.placements[1].offset = plan.placements[0].offset;
    assert!(plan.verify().is_err());
}

// ---------------------------------------------------------------------------
// Layout packing (Figure 1)
// ---------------------------------------------------------------------------

fn rand_tensor(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn prop_pack_unpack_roundtrip() {
    let mut rng = Rng64::seed_from_u64(17);
    for _ in 0..50 {
        let cb = [1usize, 2, 4, 8, 16][rng.range_usize(0, 4)];
        let d = Nchw {
            n: rng.range_usize(1, 3),
            c: cb * rng.range_usize(1, 6),
            h: rng.range_usize(1, 9),
            w: rng.range_usize(1, 9),
        };
        let x = rand_tensor(&mut rng, d.len());
        let packed = pack_nchwc(&x, d, cb).unwrap();
        let back = unpack_nchwc(&packed, d, cb).unwrap();
        assert_eq!(x, back, "roundtrip failed for {d:?} cb={cb}");
    }
}

#[test]
fn pack_semantics_pointwise() {
    // packed[n][co][h][w][ci] == src[n][co*cb+ci][h][w]
    let d = Nchw { n: 1, c: 8, h: 2, w: 2 };
    let x: Vec<f32> = (0..d.len()).map(|i| i as f32).collect();
    let cb = 4;
    let p = pack_nchwc(&x, d, cb).unwrap();
    for co in 0..2 {
        for ci in 0..cb {
            for h in 0..2 {
                for w in 0..2 {
                    let src = x[((co * cb + ci) * 2 + h) * 2 + w];
                    let dst = p[((co * (2 * 2)) + h * 2 + w) * cb + ci];
                    assert_eq!(src, dst);
                }
            }
        }
    }
}

#[test]
fn prop_nhwc_roundtrip() {
    let mut rng = Rng64::seed_from_u64(23);
    for _ in 0..50 {
        let d = Nchw {
            n: rng.range_usize(1, 3),
            c: rng.range_usize(1, 8),
            h: rng.range_usize(1, 7),
            w: rng.range_usize(1, 7),
        };
        let x = rand_tensor(&mut rng, d.len());
        let t = nchw_to_nhwc(&x, d).unwrap();
        let back = nhwc_to_nchw(&t, d).unwrap();
        assert_eq!(x, back);
    }
}

#[test]
fn pack_rejects_indivisible_channels() {
    let d = Nchw { n: 1, c: 6, h: 2, w: 2 };
    assert!(pack_nchwc(&vec![0.0; d.len()], d, 4).is_err());
}

#[test]
fn weight_pack_shape_and_content() {
    let (k, c, r, s) = (8usize, 4usize, 3usize, 3usize);
    let w: Vec<f32> = (0..k * c * r * s).map(|i| i as f32).collect();
    let (cb, kb) = (2usize, 4usize);
    let p = pack_oihw(&w, k, c, r, s, cb, kb).unwrap();
    assert_eq!(p.len(), w.len());
    // spot-check: packed[(ko,co,r,s,ci,ki)] == w[(ko*kb+ki, co*cb+ci, r, s)]
    let (ko, co, rr, ss, ci, ki) = (1usize, 1usize, 2usize, 0usize, 1usize, 3usize);
    let src = w[(((ko * kb + ki) * c + (co * cb + ci)) * r + rr) * s + ss];
    let dst = p[(((((ko * (c / cb) + co) * r + rr) * s + ss) * cb + ci) * kb) + ki];
    assert_eq!(src, dst);
}
