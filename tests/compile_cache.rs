//! Tier-1 integration tests for the content-addressed compile cache:
//! golden digest stability, key sensitivity, on-disk round-trips that
//! stay bit-identical to the cold compile, corruption tolerance, and
//! tune-record merging.

use std::fs;
use std::path::PathBuf;

use tvmq::cache::{graph_digest, overrides_digest, CacheKey, CompileCache};
use tvmq::executor::{ArenaExec, Banding, Executor};
use tvmq::graph::{
    build_resnet_ir_in, calibrate_ir, evaluate, rebatch_graph, AnchorOp, ClassKey, Graph, Layout,
    MicroKernel, Op, ScheduleOverrides, ShapeKey, StepSched, TensorTy,
};
use tvmq::tune::{merge, TaskKey, TuneRecord, TuneRecords, RECORDS_VERSION};

/// A fresh scratch dir under the system temp dir, unique per test so the
/// suite can run in parallel.
fn scratch(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tvmq-cache-test-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

/// A two-layer dense net whose constants can be appended in either
/// order; `scale` perturbs one weight so value changes are testable.
fn two_dense(swapped: bool, scale: f32) -> Graph {
    let va: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 2.0).collect();
    let vb: Vec<f32> = (0..16).map(|i| (i * i) as f32 * 0.125 * scale - 1.0).collect();
    let mut g = Graph::new();
    let x = g.add_input("x", TensorTy::f32(vec![1, 4]));
    // Node ids (and names) differ between the two orders; only the
    // dataflow is the same.
    let (wa, wb) = if swapped {
        let wb = g.add_const_f32("second", vec![4, 4], vb).unwrap();
        let wa = g.add_const_f32("first", vec![4, 4], va).unwrap();
        (wa, wb)
    } else {
        let wa = g.add_const_f32("wa", vec![4, 4], va).unwrap();
        let wb = g.add_const_f32("wb", vec![4, 4], vb).unwrap();
        (wa, wb)
    };
    let d1 = g.add("d1", Op::Dense, vec![x, wa]).unwrap();
    let d2 = g.add("d2", Op::Dense, vec![d1, wb]).unwrap();
    g.output = d2;
    g.validate().unwrap();
    g
}

#[test]
fn digest_ignores_build_order_and_names() {
    let a = two_dense(false, 1.0);
    let b = two_dense(true, 1.0);
    let (da, db) = (graph_digest(&a), graph_digest(&b));
    assert_eq!(da.graph, db.graph, "identical dataflow must share a graph digest");
    assert_eq!(da.const_pool, db.const_pool);
    let ovr = ScheduleOverrides::default();
    assert_eq!(CacheKey::of(&a, &ovr, true, 1), CacheKey::of(&b, &ovr, true, 1));
}

#[test]
fn digest_tracks_constant_values_and_layout() {
    let base = two_dense(false, 1.0);
    let tweaked = two_dense(false, 1.0001);
    assert_ne!(
        graph_digest(&base).graph,
        graph_digest(&tweaked).graph,
        "constant payloads are keyed by value"
    );
    assert_ne!(graph_digest(&base).const_pool, graph_digest(&tweaked).const_pool);

    let nchw = build_resnet_ir_in(1, 16, 7, Layout::Nchw).unwrap();
    let nhwc = build_resnet_ir_in(1, 16, 7, Layout::Nhwc).unwrap();
    assert_ne!(
        graph_digest(&nchw).graph,
        graph_digest(&nhwc).graph,
        "layout changes the compiled program, so it must change the key"
    );
}

#[test]
fn overrides_digest_tracks_knobs_but_not_threads() {
    let d0 = overrides_digest(&ScheduleOverrides::default(), true);

    // Threads are a separate key component, not part of the table digest.
    let mut threaded = ScheduleOverrides::default();
    threaded.threads = 8;
    assert_eq!(d0, overrides_digest(&threaded, true));

    let mut lanes = ScheduleOverrides::default();
    lanes.max_stack_lanes += 1;
    assert_ne!(d0, overrides_digest(&lanes, true));

    assert_ne!(d0, overrides_digest(&ScheduleOverrides::default(), false), "fuse is keyed");

    let mut per_class = ScheduleOverrides::default();
    per_class.per_class.insert(
        ClassKey { op: AnchorOp::Dense, layout: None },
        StepSched { banding: Some(Banding::Interleaved), max_bands: 2, micro: None },
    );
    assert_ne!(d0, overrides_digest(&per_class, true));

    // The register-tile knob is keyed: a microkernel geometry change can
    // never serve a plan compiled for another tile.
    let mut micro = ScheduleOverrides::default();
    micro.default_sched.micro = Some(MicroKernel::default());
    assert_ne!(d0, overrides_digest(&micro, true), "register tile is keyed");
    let mut micro2 = micro.clone();
    micro2.default_sched.micro = Some(MicroKernel { mr: 4, nr: 4, ku: 4 });
    assert_ne!(
        overrides_digest(&micro, true),
        overrides_digest(&micro2, true),
        "distinct tile geometries must key differently"
    );

    // Per-shape entries are keyed too (the per-shape tier beats per-class
    // at compile time, so it must invalidate like any other knob).
    let mut shaped = ScheduleOverrides::default();
    shaped.per_shape.insert(
        ShapeKey { class: ClassKey { op: AnchorOp::Dense, layout: None }, shape: vec![1, 4] },
        StepSched { banding: Some(Banding::Interleaved), max_bands: 2, micro: None },
    );
    assert_ne!(d0, overrides_digest(&shaped, true), "per-shape entries are keyed");

    // And keys built from them differ too.
    let g = two_dense(false, 1.0);
    assert_ne!(
        CacheKey::of(&g, &ScheduleOverrides::default(), true, 1),
        CacheKey::of(&g, &lanes, true, 1)
    );
    assert_ne!(
        CacheKey::of(&g, &ScheduleOverrides::default(), true, 1),
        CacheKey::of(&g, &ScheduleOverrides::default(), true, 4),
        "thread width is keyed (spill windows are sized for it)"
    );
}

#[test]
fn rebatched_buckets_share_the_constant_pool() {
    let template = build_resnet_ir_in(1, 16, 7, Layout::Nchw).unwrap();
    let g2 = rebatch_graph(&template, 2).unwrap();
    let g4 = rebatch_graph(&template, 4).unwrap();
    let (d2, d4) = (graph_digest(&g2), graph_digest(&g4));
    assert_ne!(d2.graph, d4.graph, "batch is part of the program");
    assert_eq!(
        d2.const_pool, d4.const_pool,
        "re-batched bucket graphs share one weight pool digest"
    );
}

#[test]
fn store_round_trip_is_bit_identical() {
    // fp32 at threads=1, int8 (quantize-realized, f32 scale fields) at
    // threads=4 — the pooled build sizes spill bands for 4 workers.
    for (threads, layout) in [(1usize, Layout::Nchw), (4usize, Layout::Nchwc(4))] {
        let dir = scratch(&format!("roundtrip-t{threads}"));
        let cache = CompileCache::open(&dir).unwrap().with_verify(true);
        let g = match layout {
            Layout::Nchw => build_resnet_ir_in(1, 16, 7, Layout::Nchw).unwrap(),
            _ => {
                // Quantize-realize so the stored program exercises the f32
                // scale (de)serialization.
                use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};
                let g1 = build_resnet_ir_in(1, 16, 7, layout).unwrap();
                let calib = calibrate_ir(&g1, 1);
                let scales = calibrate_graph(&g1, &calib).unwrap();
                QuantizeRealize { scales }.run(&g1).unwrap()
            }
        };
        let ovr = ScheduleOverrides::default();
        let cold = ArenaExec::with_schedule(&g, true, threads, &ovr).unwrap();
        let key = CacheKey::of(&g, &ovr, true, threads);

        cache.store(&key, cold.compiled()).unwrap();
        let cg = cache.load(&key, &g).expect("freshly stored entry must hit");
        let warm = ArenaExec::from_compiled(cg, threads).unwrap();

        let x = calibrate_ir(&g, 42);
        let a = cold.run(&x).unwrap();
        let b = warm.run(&x).unwrap();
        let oracle = evaluate(&g, &x).unwrap();
        let bits = |t: &tvmq::runtime::TensorData| -> Vec<u32> {
            t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits(&a), bits(&b), "threads={threads}: warm engine diverged from cold");
        assert_eq!(bits(&a), bits(&oracle), "threads={threads}: diverged from interpreter");

        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.stores, s.rejected), (1, 0, 1, 0));
        let _ = fs::remove_dir_all(&dir);
    }
}

#[test]
fn corrupt_and_future_entries_are_logged_misses() {
    let dir = scratch("corrupt");
    let cache = CompileCache::open(&dir).unwrap();
    let g = two_dense(false, 1.0);
    let ovr = ScheduleOverrides::default();
    let exec = ArenaExec::with_schedule(&g, true, 1, &ovr).unwrap();
    let key = CacheKey::of(&g, &ovr, true, 1);
    cache.store(&key, exec.compiled()).unwrap();
    let entry = dir.join(format!("{}.json", key.file_stem()));
    assert!(entry.is_file(), "entry file {entry:?} must exist");

    // Truncated garbage: a miss, never an error.
    fs::write(&entry, "{\"kind\": \"tvmq-compile-cache\", \"vers").unwrap();
    assert!(cache.load(&key, &g).is_none());

    // A future store version: also a miss.
    fs::write(&entry, "{\"kind\": \"tvmq-compile-cache\", \"version\": 999}").unwrap();
    assert!(cache.load(&key, &g).is_none());

    let s = cache.stats();
    assert_eq!(s.misses, 2);
    assert_eq!(s.rejected, 2, "unusable entries are counted as rejected");

    // The cold path overwrites the bad entry and the key hits again.
    cache.store(&key, exec.compiled()).unwrap();
    assert!(cache.load(&key, &g).is_some());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_packed_payload_is_rejected_as_a_logged_miss() {
    use tvmq::graph::passes::{calibrate_graph, Pass, QuantizeRealize};

    let dir = scratch("packed");
    let cache = CompileCache::open(&dir).unwrap();

    // A quantized packed-layout model under forced microkernels: the
    // stored entry carries pre-packed weight-panel metadata (src, layout,
    // len, digest) — the panels themselves are rebuilt from the constant
    // pool on load and re-verified against the recorded digest.
    let g1 = build_resnet_ir_in(1, 12, 7, Layout::Nchwc(4)).unwrap();
    let calib = calibrate_ir(&g1, 1);
    let scales = calibrate_graph(&g1, &calib).unwrap();
    let g = QuantizeRealize { scales }.run(&g1).unwrap();
    let ovr = ScheduleOverrides {
        default_sched: StepSched {
            banding: None,
            max_bands: 0,
            micro: Some(MicroKernel::default()),
        },
        ..ScheduleOverrides::default()
    };
    let exec = ArenaExec::with_schedule(&g, true, 1, &ovr).unwrap();
    assert!(
        !exec.compiled().packed.is_empty(),
        "forced-micro int8 model must pre-pack at least one weight panel"
    );
    let key = CacheKey::of(&g, &ovr, true, 1);
    cache.store(&key, exec.compiled()).unwrap();

    // Sanity: the untampered entry hits, the warm engine re-packs the
    // panels deterministically, and both engines match the oracle.
    let cg = cache.load(&key, &g).expect("fresh packed entry must hit");
    assert_eq!(cg.packed.len(), exec.compiled().packed.len());
    let warm = ArenaExec::from_compiled(cg, 1).unwrap();
    let x = calibrate_ir(&g, 42);
    let want = evaluate(&g, &x).unwrap();
    assert_eq!(want, exec.run(&x).unwrap(), "cold packed engine diverged");
    assert_eq!(want, warm.run(&x).unwrap(), "warm packed engine diverged");

    let entry = dir.join(format!("{}.json", key.file_stem()));
    let text = fs::read_to_string(&entry).unwrap();

    // Tamper the first packed panel's recorded digest: the rebuilt panel
    // no longer matches, so the entry is a logged miss — never an error,
    // never a silently wrong engine.
    let pi = text.find("\"packed\"").expect("entry must carry packed metadata");
    let di = text[pi..].find("\"digest\"").expect("panel must carry a digest") + pi;
    let start = di + text[di..].find(':').unwrap() + 1;
    let start = start + text[start..].find('"').unwrap() + 1;
    let old = text.as_bytes()[start] as char;
    let new = if old == '0' { '1' } else { '0' };
    let mut tampered = text.clone();
    tampered.replace_range(start..start + 1, &new.to_string());
    assert_ne!(tampered, text);
    fs::write(&entry, &tampered).unwrap();
    assert!(cache.load(&key, &g).is_none(), "corrupt packed digest must miss");

    // A future pre-pack format version: same story (the layout contract
    // changed, so the whole entry is unusable).
    let future = text.replace("\"pack_format\": 1", "\"pack_format\": 999");
    assert_ne!(future, text, "pack_format field must be present to rewrite");
    fs::write(&entry, &future).unwrap();
    assert!(cache.load(&key, &g).is_none(), "future pack_format must miss");

    let s = cache.stats();
    assert_eq!(s.hits, 1);
    assert_eq!(s.misses, 2);
    assert_eq!(s.rejected, 2, "both tampered entries count as rejected");

    // The cold path overwrites and the key serves again.
    cache.store(&key, exec.compiled()).unwrap();
    assert!(cache.load(&key, &g).is_some());
    let _ = fs::remove_dir_all(&dir);
}

/// A hand-built single-record run for merge tests.
fn run(ns: f64, best_ns: f64, max_bands: usize, banding: Option<Banding>) -> TuneRecords {
    TuneRecords {
        model: "resnet-ir".into(),
        layout: "nchw".into(),
        precision: "fp32".into(),
        image: 16,
        batch: 1,
        threads: 1,
        fuse: true,
        max_stack_lanes: 8,
        records: vec![TuneRecord {
            key: TaskKey {
                op: AnchorOp::Conv2d,
                layout: Some(Layout::Nchw),
                precision: "fp32".into(),
                shape: vec![1, 16, 8, 8],
                threads: 1,
            },
            sched: StepSched { banding, max_bands, micro: None },
            ns_per_iter: Some(ns),
        }],
        trials: 4,
        rejected: 0,
        default_ns_per_iter: 1000.0,
        best_ns_per_iter: best_ns,
    }
}

#[test]
fn merge_keeps_best_measured_config_per_key() {
    let slow = run(100.0, 100.0, 1, Some(Banding::Contiguous));
    let fast = run(80.0, 80.0, 3, Some(Banding::Interleaved));
    let merged = merge(&[slow.clone(), fast.clone()]).unwrap();
    assert_eq!(merged.records.len(), 1, "same task key must collapse to one record");
    assert_eq!(merged.records[0].sched, fast.records[0].sched, "lowest ns/iter wins");
    assert_eq!(merged.records[0].ns_per_iter, Some(80.0));
    // Run-level base comes from the fastest run; accounting sums.
    assert_eq!(merged.best_ns_per_iter, 80.0);
    assert_eq!(merged.trials, 8);

    // Order independence: the winner does not depend on argument order.
    let flipped = merge(&[fast, slow]).unwrap();
    assert_eq!(flipped.records[0].ns_per_iter, Some(80.0));
}

#[test]
fn records_schema_versioning_round_trips_and_rejects_the_future() {
    let dir = scratch("records");
    fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    let r = run(90.0, 90.0, 2, None);
    r.save(&path).unwrap();
    assert_eq!(TuneRecords::load(&path).unwrap(), r);

    // A file written by a future tvmq: strict load errors, the serve
    // path's lenient load falls back to defaults (None) instead.
    let text = fs::read_to_string(&path).unwrap();
    let future = text.replace(
        &format!("\"version\": {RECORDS_VERSION}"),
        "\"version\": 99",
    );
    assert_ne!(text, future, "version field must be present to rewrite");
    fs::write(&path, &future).unwrap();
    assert!(TuneRecords::load(&path).is_err());
    assert!(TuneRecords::load_lenient(&path).is_none());

    // Corrupt file: same story.
    fs::write(&path, "not json at all").unwrap();
    assert!(TuneRecords::load_lenient(&path).is_none());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn scan_tune_records_skips_cache_entries_and_bad_files() {
    let dir = scratch("scan");
    let cache = CompileCache::open(&dir).unwrap();
    // A compile-cache entry, a valid records file, and a corrupt one all
    // share the directory; only the valid records file is returned.
    let g = two_dense(false, 1.0);
    let ovr = ScheduleOverrides::default();
    let exec = ArenaExec::with_schedule(&g, true, 1, &ovr).unwrap();
    cache.store(&CacheKey::of(&g, &ovr, true, 1), exec.compiled()).unwrap();
    let r = run(70.0, 70.0, 1, Some(Banding::Contiguous));
    r.save(dir.join("tuned.json")).unwrap();
    fs::write(dir.join("broken.json"), "{").unwrap();

    let found = tvmq::cache::scan_tune_records(&dir);
    assert_eq!(found.len(), 1);
    assert_eq!(found[0].1, r);
    let _ = fs::remove_dir_all(&dir);
}
