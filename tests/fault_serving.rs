//! Fault-injected serving: the coordinator under deterministic engine
//! failure, panic, worker death, stall, and build-time faults — driven
//! through `InferenceServer::start_with` over a `FaultyFactory`.
//!
//! The properties pinned here (the tentpole's serving half):
//! - per-request errors propagate without deadlock and are counted in
//!   `ServerStats.errors`;
//! - the worker survives an engine *panic* and keeps serving;
//! - true worker death (`Fault::Die`) resolves every pending reply with
//!   an error — promptly, never a hang — and later submissions fail fast;
//! - build-time faults fail startup cleanly instead of hanging it;
//! - shutdown with in-flight requests resolves every `PendingReply`
//!   (bounded by `wait_timeout`, so a regression hangs the assert, not
//!   the suite).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};
use tvmq::check::fault::{silence_injected_faults, Fault, FaultPlan, FaultyFactory};
use tvmq::coordinator::{InferenceServer, PendingReply, Rejected, ServeConfig, WaitError};
use tvmq::executor::{EngineFactory, EngineKind, EngineSpec, ExecSnapshot, Executor};
use tvmq::runtime::{DType, TensorData};

const DIM: usize = 4;
const CLASSES: usize = 8;

/// Minimal deterministic engine (same construction as tests/coordinator.rs):
/// row `i`'s logits peak at `round(input[i][0])`.
struct MockExec {
    batch: usize,
    calls: Arc<Mutex<Vec<usize>>>,
}

impl Executor for MockExec {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        if input.shape != vec![self.batch, DIM] {
            return Err(anyhow!("mock: bad input shape {:?}", input.shape));
        }
        self.calls.lock().unwrap().push(self.batch);
        let x = input.as_f32_slice()?;
        let mut out = vec![0f32; self.batch * CLASSES];
        for i in 0..self.batch {
            let v = x[i * DIM];
            for j in 0..CLASSES {
                out[i * CLASSES + j] = -((j as f32) - v).abs();
            }
        }
        TensorData::from_f32(vec![self.batch, CLASSES], &out)
    }

    fn name(&self) -> &str {
        "mock"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        (vec![self.batch, DIM], DType::F32)
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        (vec![self.batch, CLASSES], DType::F32)
    }

    fn counters(&self) -> ExecSnapshot {
        ExecSnapshot {
            invocations: 0,
            dispatches: 0,
            dynamic_allocs: 0,
            boundary_bytes: 0,
            instructions: 0,
        }
    }
}

struct MockFactory {
    buckets: Vec<usize>,
    calls: Arc<Mutex<Vec<usize>>>,
}

impl MockFactory {
    fn new(buckets: &[usize]) -> Self {
        MockFactory { buckets: buckets.to_vec(), calls: Arc::new(Mutex::new(Vec::new())) }
    }
}

impl EngineFactory for MockFactory {
    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        Ok(Box::new(MockExec { batch, calls: self.calls.clone() }))
    }
}

fn image(class: usize) -> TensorData {
    TensorData::from_f32(vec![1, DIM], &[class as f32; DIM]).unwrap()
}

fn cfg(max_batch: usize, timeout_ms: u64) -> ServeConfig {
    ServeConfig {
        spec: EngineSpec::new(EngineKind::Arena),
        max_batch,
        batch_timeout: Duration::from_millis(timeout_ms),
        ..ServeConfig::default()
    }
}

/// Bound every wait so a lost reply fails the assert instead of hanging
/// the suite.
const REPLY_BOUND: Duration = Duration::from_secs(10);

/// Append one JSONL record to the CI summary artifact (same file the
/// model-check suite writes its explored-schedule counts to).
fn record_summary(scenario: &str, requests: usize, ok: usize, errors: usize) {
    let Some(path) = std::env::var_os("TVMQ_CHECK_SUMMARY") else {
        return;
    };
    use std::io::Write;
    let line = format!(
        "{{\"scenario\":\"{scenario}\",\"requests\":{requests},\"ok\":{ok},\"errors\":{errors}}}\n"
    );
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = f.write_all(line.as_bytes());
    }
}

#[test]
fn engine_error_fails_its_batch_and_serving_continues() {
    let factory = FaultyFactory::new(MockFactory::new(&[1]))
        .run_faults(FaultPlan::script([Some(Fault::Error)]));
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();

    let err = server.submit(image(2)).unwrap().wait_timeout(REPLY_BOUND).unwrap_err();
    assert!(err.to_string().contains("injected engine run error"), "got: {err}");

    // The very next request is served normally by the same worker.
    let reply = server.submit(image(3)).unwrap().wait_timeout(REPLY_BOUND).unwrap();
    assert_eq!(reply.class, 3);

    let stats = server.stats();
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.requests, 1);
    server.shutdown().unwrap();
}

#[test]
fn engine_panic_is_contained_and_serving_continues() {
    silence_injected_faults();
    let factory = FaultyFactory::new(MockFactory::new(&[1]))
        .run_faults(FaultPlan::script([Some(Fault::Panic)]));
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();

    // The panic becomes a per-batch error; the worker stays alive.
    let err = server.submit(image(1)).unwrap().wait_timeout(REPLY_BOUND).unwrap_err();
    assert!(err.to_string().contains("engine panicked"), "got: {err}");
    assert!(err.to_string().contains("injected engine run panic"), "got: {err}");

    let reply = server.submit(image(5)).unwrap().wait_timeout(REPLY_BOUND).unwrap();
    assert_eq!(reply.class, 5);

    let stats = server.stats();
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.requests, 1);
    // Stats stay readable even though a panic crossed the worker (the
    // lock recovers from poisoning rather than cascading).
    assert_eq!(stats.batches, 1);
    server.shutdown().unwrap();
}

/// The killed-worker regression: `Fault::Die` re-raises out of the
/// worker thread.  The in-flight reply must resolve with an error
/// (bounded, no hang) and subsequent submissions must fail promptly.
#[test]
fn worker_death_resolves_pending_replies_and_fails_later_submits() {
    silence_injected_faults();
    let factory = FaultyFactory::new(MockFactory::new(&[1]))
        .run_faults(FaultPlan::script([Some(Fault::Die)]));
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();

    let pending = server.submit(image(0)).unwrap();
    let err = pending.wait_timeout(REPLY_BOUND).unwrap_err();
    assert!(
        err.to_string().contains("dropped request") || err.to_string().contains("timed out"),
        "a dead worker must drop the reply channel, got: {err}"
    );

    // The down flag is raised by the worker's drop guard during unwind;
    // give the dying thread a bounded moment, then submits must fail.
    let deadline = std::time::Instant::now() + REPLY_BOUND;
    loop {
        match server.submit(image(1)) {
            Err(e) => {
                assert!(e.to_string().contains("down"), "got: {e}");
                break;
            }
            Ok(reply) => {
                // Raced the unwind: the enqueued job can never be served;
                // its reply must still resolve to an error, not hang.
                assert!(reply.wait_timeout(REPLY_BOUND).is_err());
                assert!(
                    std::time::Instant::now() < deadline,
                    "submit never started failing after worker death"
                );
            }
        }
    }

    // Joining a dead worker reports the death instead of pretending a
    // clean exit.
    assert!(server.shutdown().is_err());
}

#[test]
fn build_error_fails_startup_cleanly() {
    let factory = FaultyFactory::new(MockFactory::new(&[1, 2]))
        .build_faults(FaultPlan::script([Some(Fault::Error)]));
    let err = InferenceServer::start_with(factory, cfg(2, 1)).unwrap_err();
    assert!(err.to_string().contains("injected factory build error"), "got: {err}");
}

#[test]
fn build_panic_fails_startup_instead_of_hanging_it() {
    silence_injected_faults();
    let factory = FaultyFactory::new(MockFactory::new(&[1, 2]))
        .build_faults(FaultPlan::script([None, Some(Fault::Panic)]));
    let err = InferenceServer::start_with(factory, cfg(2, 1)).unwrap_err();
    assert!(err.to_string().contains("worker died during startup"), "got: {err}");
}

/// Seeded soak: a 25% error rate over 40 requests across mixed buckets.
/// Every single reply resolves (success or error — never a timeout), and
/// the stats ledger balances: every request is accounted as served or
/// errored.
#[test]
fn seeded_error_soak_never_loses_a_reply_and_stats_balance() {
    let factory = FaultyFactory::new(MockFactory::new(&[1, 2, 4]))
        .run_faults(FaultPlan::seeded(0xFA17, 25, Fault::Error));
    let server = InferenceServer::start_with(factory, cfg(4, 1)).unwrap();

    const N: usize = 40;
    let mut outcomes = (0usize, 0usize);
    for c in 0..N {
        let pending = server.submit(image(c % CLASSES)).unwrap();
        match pending.wait_timeout(REPLY_BOUND) {
            Ok(reply) => {
                assert_eq!(reply.class, c % CLASSES, "reply routed to the wrong request");
                outcomes.0 += 1;
            }
            Err(e) => {
                assert!(
                    e.to_string().contains("injected engine run error"),
                    "only the injected fault may fail requests, got: {e}"
                );
                outcomes.1 += 1;
            }
        }
    }
    assert_eq!(outcomes.0 + outcomes.1, N);
    assert!(outcomes.0 > 0, "soak produced no successes");
    assert!(outcomes.1 > 0, "soak produced no injected errors — plan never fired");

    let stats = server.stats();
    assert_eq!(
        stats.requests + stats.errors,
        N as u64,
        "every request must be accounted exactly once: {stats:?}"
    );
    assert_eq!(stats.requests, outcomes.0 as u64);
    assert_eq!(stats.errors, outcomes.1 as u64);
    record_summary("fault-soak-seeded-25pct", N, outcomes.0, outcomes.1);
    server.shutdown().unwrap();
}

/// Shutdown with requests still in flight (the engine is stalled by an
/// injected delay): every pending reply resolves within the bound, new
/// submissions fail immediately, and the join is clean.
#[test]
fn shutdown_with_in_flight_requests_resolves_every_reply() {
    let factory = FaultyFactory::new(MockFactory::new(&[1])).run_faults(FaultPlan::script([
        Some(Fault::Delay(Duration::from_millis(50))),
        Some(Fault::Delay(Duration::from_millis(50))),
        Some(Fault::Delay(Duration::from_millis(50))),
    ]));
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();

    let pending: Vec<PendingReply> =
        (0..3).map(|c| server.submit(image(c)).unwrap()).collect();
    server.request_shutdown();

    // Submitting after shutdown fails promptly — no phantom PendingReply.
    let err = server.submit(image(7)).unwrap_err();
    assert!(err.to_string().contains("down"), "got: {err}");

    // The queued requests were accepted before shutdown: each resolves.
    for (c, p) in pending.into_iter().enumerate() {
        let reply = p
            .wait_timeout(REPLY_BOUND)
            .unwrap_or_else(|e| panic!("in-flight request {c} never resolved: {e}"));
        assert_eq!(reply.class, c);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 0);
    record_summary("fault-shutdown-in-flight", 3, 3, 0);
    server.shutdown().unwrap();
}

/// The wait-time errors are typed, not one blurred message: a client-side
/// timeout downcasts to [`WaitError::Timeout`] (the request may still
/// complete), worker death to [`WaitError::WorkerDied`].
#[test]
fn wait_errors_are_typed_timeout_vs_worker_death() {
    silence_injected_faults();
    // Timeout: the engine is merely slow; a 10ms wait on a 300ms stall
    // must say "timed out", not "worker died".
    let factory = FaultyFactory::new(MockFactory::new(&[1]))
        .run_faults(FaultPlan::script([Some(Fault::Delay(Duration::from_millis(300)))]));
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();
    let err = server
        .submit(image(2))
        .unwrap()
        .wait_timeout(Duration::from_millis(10))
        .unwrap_err();
    assert_eq!(
        err.downcast_ref::<WaitError>(),
        Some(&WaitError::Timeout),
        "got: {err}"
    );
    server.shutdown().unwrap();

    // Death: the worker is gone; the reply channel drops and the error
    // says so.
    let factory = FaultyFactory::new(MockFactory::new(&[1]))
        .run_faults(FaultPlan::script([Some(Fault::Die)]));
    let server = InferenceServer::start_with(factory, cfg(1, 1)).unwrap();
    let err = server.submit(image(0)).unwrap().wait_timeout(REPLY_BOUND).unwrap_err();
    assert_eq!(
        err.downcast_ref::<WaitError>(),
        Some(&WaitError::WorkerDied),
        "got: {err}"
    );
    assert!(server.shutdown().is_err());
}

/// Backpressure is a typed shed, not an unbounded queue: with the single
/// worker stalled and the admission queue at its bound, further submits
/// fail immediately with [`Rejected::Overloaded`] carrying the bound —
/// and every *accepted* request is still served correctly afterwards.
#[test]
fn overloaded_queue_sheds_with_typed_error_and_serves_the_accepted() {
    let factory = FaultyFactory::new(MockFactory::new(&[1]))
        .run_faults(FaultPlan::script([Some(Fault::Delay(Duration::from_millis(300)))]));
    let server = InferenceServer::start_with(
        factory,
        ServeConfig { queue_bound: 2, ..cfg(1, 1) },
    )
    .unwrap();

    // First request occupies the worker (stalled inside the engine);
    // then overfill the bound-2 queue.
    let stalled = server.submit(image(1)).unwrap();
    // Give the worker a moment to pop the stalled job off the queue.
    std::thread::sleep(Duration::from_millis(50));
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for c in 0..6 {
        match server.submit(image(c % CLASSES)) {
            Ok(p) => accepted.push((c % CLASSES, p)),
            Err(e) => {
                match e.downcast_ref::<Rejected>() {
                    Some(&Rejected::Overloaded { bound, depth }) => {
                        assert_eq!(bound, 2, "shed must report the configured bound");
                        assert!(depth >= bound, "shed below the bound: {e}");
                    }
                    other => panic!("expected Overloaded, got {other:?}: {e}"),
                }
                shed += 1;
            }
        }
    }
    assert!(shed >= 1, "overfilling a bound-2 queue by 6 must shed");
    assert!(accepted.len() >= 2, "the queue must still accept up to its bound");

    // The stalled request and every accepted one resolve correctly.
    assert_eq!(stalled.wait_timeout(REPLY_BOUND).unwrap().class, 1);
    for (want, p) in accepted {
        assert_eq!(p.wait_timeout(REPLY_BOUND).unwrap().class, want);
    }
    let stats = server.stats();
    assert_eq!(stats.shed, shed as u64, "server ledger must count every shed");
    assert_eq!(stats.errors, 0, "sheds are not errors");
    record_summary("fault-overload-shed", 7, 1 + (7 - 1 - shed), shed);
    server.shutdown().unwrap();
}

/// The multi-worker death matrix: kill workers under load via per-worker
/// fault plans and assert the failover contract — survivors keep serving
/// with zero wrong replies, in-flight jobs on dead workers error promptly,
/// and shutdown reports the deaths.
#[test]
fn killing_workers_under_load_leaves_survivors_serving() {
    silence_injected_faults();
    // Workers 0 and 1 die on their first served batch; worker 2 is clean.
    let factory = FaultyFactory::new(MockFactory::new(&[1])).run_faults(
        FaultPlan::per_worker(
            [FaultPlan::script([Some(Fault::Die)]), FaultPlan::script([Some(Fault::Die)])],
            FaultPlan::none(),
        ),
    );
    let server = Arc::new(
        InferenceServer::start_with(factory, ServeConfig { workers: 3, ..cfg(1, 1) }).unwrap(),
    );
    assert_eq!(server.alive_workers(), 3);

    // Load until both doomed workers have served (and died), bounded so a
    // starved worker fails the test instead of hanging it.  Every reply
    // either carries the RIGHT class or is a prompt typed error — a wrong
    // class is an immediate failure.
    let deadline = std::time::Instant::now() + REPLY_BOUND;
    let (mut ok, mut errors) = (0usize, 0usize);
    while server.alive_workers() > 1 {
        assert!(
            std::time::Instant::now() < deadline,
            "doomed workers never served a batch (ok={ok} errors={errors})"
        );
        // Burst one request per worker so every blocked worker gets
        // woken — a serial drip could let the clean worker starve the
        // doomed ones of work indefinitely.
        let pending: Vec<(usize, PendingReply)> = (0..3)
            .map(|k| {
                let c = (ok + errors + k) % CLASSES;
                (c, server.submit(image(c)).expect("a worker survives"))
            })
            .collect();
        for (c, p) in pending {
            match p.wait_timeout(REPLY_BOUND) {
                Ok(reply) => {
                    assert_eq!(reply.class, c, "reply routed to the wrong request");
                    ok += 1;
                }
                Err(e) => {
                    assert_eq!(
                        e.downcast_ref::<WaitError>(),
                        Some(&WaitError::WorkerDied),
                        "in-flight on a dying worker must error as WorkerDied: {e}"
                    );
                    errors += 1;
                }
            }
        }
    }
    assert_eq!(server.alive_workers(), 1);
    assert_eq!(errors, 2, "exactly the two Die batches may fail");

    // The survivor keeps serving: the next submissions all succeed.
    for c in 0..4 {
        let reply = server.submit(image(c)).unwrap().wait_timeout(REPLY_BOUND).unwrap();
        assert_eq!(reply.class, c);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, ok as u64 + 4);
    record_summary("fault-multi-worker-kill", ok + errors + 4, ok + 4, errors);
    assert!(
        Arc::try_unwrap(server).ok().expect("no clients left").shutdown().is_err(),
        "join must report the dead workers"
    );
}

/// Per-worker build faults make multi-worker startup failures
/// deterministic: worker 1's build errors, worker 0's succeeds, and
/// startup reports the injected error instead of hanging or succeeding.
#[test]
fn per_worker_build_fault_fails_startup_deterministically() {
    let factory = FaultyFactory::new(MockFactory::new(&[1])).build_faults(
        FaultPlan::per_worker(
            [FaultPlan::none(), FaultPlan::script([Some(Fault::Error)])],
            FaultPlan::none(),
        ),
    );
    let err = InferenceServer::start_with(factory, ServeConfig { workers: 2, ..cfg(1, 1) })
        .unwrap_err();
    assert!(err.to_string().contains("injected factory build error"), "got: {err}");
}
