//! In-situ engine hot-swap under live load, fault-injected.
//!
//! Two layers:
//! - a mock-engine test that tags every reply with its engine
//!   generation and injects failing and wrong-batch upgrade builds,
//!   proving each request is served by **exactly one** generation and
//!   that bad upgrades can never take a worker down or leak a reply;
//! - a real-arena test that swaps a live bucket engine for a
//!   differently-compiled (unfused) program mid-load and asserts every
//!   reply before, during, and after the swap stays bit-identical to
//!   the interpreter oracle — zero wrong bytes.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};
use tvmq::coordinator::insitu::UpgradeSlot;
use tvmq::coordinator::{InferenceServer, ServeConfig};
use tvmq::executor::{
    ArenaExec, EngineKind, EngineSpec, ExecCounters, ExecSnapshot, Executor, NativeArenaFactory,
    Precision,
};
use tvmq::graph::{compile_graph_with, evaluate, ScheduleOverrides};
use tvmq::runtime::{DType, TensorData};
use tvmq::util::rng::Rng64;

const DIM: usize = 4;
const CLASSES: usize = 8;

/// Deterministic engine that stamps every logit with its `tag`, so a
/// reply's bytes identify exactly which engine generation served it.
struct TagExec {
    batch: usize,
    tag: f32,
}

impl Executor for TagExec {
    fn run(&self, input: &TensorData) -> Result<TensorData> {
        if input.shape != vec![self.batch, DIM] {
            return Err(anyhow!("tag exec: bad input shape {:?}", input.shape));
        }
        TensorData::from_f32(vec![self.batch, CLASSES], &vec![self.tag; self.batch * CLASSES])
    }

    fn name(&self) -> &str {
        "tag"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn input_desc(&self) -> (Vec<usize>, DType) {
        (vec![self.batch, DIM], DType::F32)
    }

    fn output_desc(&self) -> (Vec<usize>, DType) {
        (vec![self.batch, CLASSES], DType::F32)
    }

    fn counters(&self) -> ExecSnapshot {
        ExecCounters::default().snapshot()
    }
}

struct TagFactory {
    slot: Arc<UpgradeSlot>,
}

impl tvmq::executor::EngineFactory for TagFactory {
    fn buckets(&self) -> Vec<usize> {
        vec![1, 2]
    }

    fn build(&self, batch: usize) -> Result<Box<dyn Executor>> {
        // Generation 0: tag 0.0.
        Ok(Box::new(TagExec { batch, tag: 0.0 }))
    }

    fn upgrade_slot(&self) -> Option<Arc<UpgradeSlot>> {
        Some(self.slot.clone())
    }
}

#[test]
fn faulty_upgrades_never_leak_and_each_reply_is_one_generation() {
    const GOOD_TAG: f32 = 1.0;
    const BAD_TAG: f32 = 9.0;
    let slot = UpgradeSlot::new();
    let server = InferenceServer::start_with(
        TagFactory { slot: slot.clone() },
        ServeConfig {
            spec: EngineSpec::new(EngineKind::Arena),
            max_batch: 2,
            batch_timeout: Duration::from_millis(1),
            workers: 2,
            ..ServeConfig::default()
        },
    )
    .unwrap();

    let img = TensorData::from_f32(vec![1, DIM], &[0.5; DIM]).unwrap();
    let mut saw_upgraded = false;
    for i in 0..300usize {
        match i {
            // Injected build failure: must be logged and skipped, the
            // gen-0 engine keeps serving.
            40 => {
                slot.publish(
                    1,
                    1.0,
                    2.0,
                    "injected failing build".into(),
                    Box::new(|| Err(anyhow!("injected upgrade build failure"))),
                );
            }
            // Wrong-batch build: the worker must reject it at adoption.
            80 => {
                slot.publish(
                    1,
                    1.0,
                    2.0,
                    "wrong-batch build".into(),
                    Box::new(|| Ok(Box::new(TagExec { batch: 7, tag: BAD_TAG }) as Box<dyn Executor>)),
                );
            }
            // The good upgrade, for both buckets.
            120 => {
                for b in [1usize, 2] {
                    slot.publish(
                        b,
                        1.0,
                        2.0,
                        format!("good upgrade bucket {b}"),
                        Box::new(move || {
                            Ok(Box::new(TagExec { batch: b, tag: GOOD_TAG }) as Box<dyn Executor>)
                        }),
                    );
                }
            }
            _ => {}
        }
        let out = server.submit_blocking(img.clone()).unwrap();
        let logits = out.logits.as_f32().unwrap();
        // Exactly one generation per reply: every byte carries one tag.
        let first = logits[0];
        assert!(
            logits.iter().all(|v| v.to_bits() == first.to_bits()),
            "request {i}: mixed-generation reply {logits:?}"
        );
        assert!(
            first == 0.0 || first == GOOD_TAG,
            "request {i}: served by a rejected engine (tag {first})"
        );
        if i < 120 {
            assert_eq!(first, 0.0, "request {i}: upgraded before a good build existed");
        }
        if first == GOOD_TAG {
            saw_upgraded = true;
        }
    }
    assert!(saw_upgraded, "the good upgrade was never adopted");

    let stats = server.stats();
    assert_eq!(stats.errors, 0, "no request may fail across faulty upgrades");
    assert_eq!(stats.requests, 300);
    server.shutdown().unwrap();
}

/// Drift-driven re-tune end to end, fault-injected: a planted latency
/// step-change must arm **exactly one** re-tune request, and the swap it
/// drives must survive an injected failing build without dropping a
/// request or leaking a rejected engine into a reply.
#[test]
fn planted_drift_triggers_exactly_one_retune_and_swap_survives_faults() {
    use tvmq::telem::{DriftConfig, Telemetry};

    const RETUNED_TAG: f32 = 2.0;
    let telem = Telemetry::new(DriftConfig {
        baseline: 64,
        window: 16,
        ratio: 1.5,
        sustain: 2,
    });
    let slot = UpgradeSlot::new();
    let server = InferenceServer::start_with_telemetry(
        TagFactory { slot: slot.clone() },
        ServeConfig {
            spec: EngineSpec::new(EngineKind::Arena),
            max_batch: 2,
            batch_timeout: Duration::from_millis(1),
            workers: 2,
            ..ServeConfig::default()
        },
        Some(Arc::clone(&telem)),
    )
    .unwrap();

    // Phase 1 — stationary seeded traffic: jittered ~800µs latencies
    // (bucket-stable around p50) must never read as drift.
    let mut rng = Rng64::seed_from_u64(17);
    for _ in 0..200 {
        telem.observe_latency_us(750 + (rng.f32() * 100.0) as u64);
    }
    assert_eq!(telem.drift_triggers(), 0, "stationary trace must not trigger");
    assert!(!telem.retune_pending());

    // Phase 2 — planted step-change: a sustained ~8× regression must
    // trigger exactly once (the detector re-baselines after firing, so
    // the persisting slow level is the new normal, not a second drift).
    for _ in 0..200 {
        telem.observe_latency_us(6200 + (rng.f32() * 400.0) as u64);
    }
    assert_eq!(telem.drift_triggers(), 1, "planted regression triggers exactly once");
    assert!(telem.retune_pending());
    assert!(telem.take_retune_request(), "the armed request is claimable");
    assert!(
        !telem.take_retune_request(),
        "claims coalesce: one trigger, one re-tune pass"
    );

    // Phase 3 — the drift-driven rebuild, fault-injected: the first
    // build fails (must be skipped, gen 0 keeps serving), then the good
    // rebuilds land for both buckets and the workers adopt them at a
    // batch boundary while requests keep flowing.
    slot.publish(
        1,
        1.0,
        2.0,
        "injected failing drift rebuild".into(),
        Box::new(|| Err(anyhow!("injected drift-rebuild failure"))),
    );
    for b in [1usize, 2] {
        slot.publish(
            b,
            1.0,
            2.0,
            format!("drift re-tune bucket {b}"),
            Box::new(move || {
                Ok(Box::new(TagExec { batch: b, tag: RETUNED_TAG }) as Box<dyn Executor>)
            }),
        );
    }
    let img = TensorData::from_f32(vec![1, DIM], &[0.5; DIM]).unwrap();
    let mut saw_retuned = false;
    for i in 0..200usize {
        let out = server.submit_blocking(img.clone()).unwrap();
        let logits = out.logits.as_f32().unwrap();
        let first = logits[0];
        assert!(
            logits.iter().all(|v| v.to_bits() == first.to_bits()),
            "request {i}: mixed-generation reply {logits:?}"
        );
        assert!(
            first == 0.0 || first == RETUNED_TAG,
            "request {i}: served by a rejected engine (tag {first})"
        );
        saw_retuned |= first == RETUNED_TAG;
    }
    assert!(saw_retuned, "the drift-driven rebuild was never adopted");

    let stats = server.stats();
    assert_eq!(stats.errors, 0, "no request may fail across the drift re-tune");
    assert_eq!(stats.requests, 200);
    server.shutdown().unwrap();
}

const IMAGE: usize = 12;

fn seeded_image(seed: u64) -> TensorData {
    let mut rng = Rng64::seed_from_u64(seed);
    let vals: Vec<f32> = (0..3 * IMAGE * IMAGE).map(|_| rng.normal() * 0.5).collect();
    TensorData::from_f32(vec![1, 3, IMAGE, IMAGE], &vals).unwrap()
}

#[test]
fn live_arena_swap_keeps_logits_bit_exact() {
    let spec = EngineSpec::new(EngineKind::Arena).precision(Precision::Fp32);
    let slot = UpgradeSlot::new();
    let factory = NativeArenaFactory::new(spec, &[1, 2], IMAGE, 1)
        .unwrap()
        .with_upgrade_slot(slot.clone());
    let g1 = factory.graph(1).unwrap();

    // The replacement: the same graph compiled *differently* (epilogue
    // fusion off) — semantically identical, structurally distinct, so the
    // swap is observable in the program while the bytes must not move.
    let cg = compile_graph_with(&g1, false, &ScheduleOverrides::default()).unwrap();
    let built = Arc::new(AtomicBool::new(false));

    let server = InferenceServer::start_with(
        factory,
        ServeConfig {
            spec,
            max_batch: 2,
            batch_timeout: Duration::from_millis(1),
            workers: 2,
            ..ServeConfig::default()
        },
    )
    .unwrap();

    for i in 0..120u64 {
        if i == 40 {
            let (cg, built) = (cg.clone(), built.clone());
            slot.publish(
                1,
                1.0,
                2.0,
                "unfused recompile of bucket 1".into(),
                Box::new(move || {
                    built.store(true, Ordering::SeqCst);
                    Ok(Box::new(ArenaExec::from_compiled(cg.clone(), 1)?) as Box<dyn Executor>)
                }),
            );
        }
        let img = seeded_image(i);
        let reply = server.submit_blocking(img.clone()).unwrap();
        let want = evaluate(&g1, &img).unwrap();
        let got_bits: Vec<u32> =
            reply.logits.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        let want_bits: Vec<u32> =
            want.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "request {i}: logits moved across the hot swap");
    }
    assert!(
        built.load(Ordering::SeqCst),
        "the published upgrade was never built by a worker"
    );
    let stats = server.stats();
    assert_eq!(stats.errors, 0);
    server.shutdown().unwrap();
}
