//! Differential tests for the arena executor: `ArenaExec` must reproduce
//! `interp::evaluate` **bit-for-bit** (TensorData equality compares raw
//! bytes) across randomized graphs — fp32 and quantize-realized, all three
//! layouts — at every thread fan-out, plus the static-plan invariants the
//! engine's aliasing safety rests on.

use tvmq::executor::{ArenaExec, Executor};
use tvmq::graph::passes::{
    calibrate_graph, AlterConvLayout, CancelLayoutTransforms, ConstantFold, Pass,
    PassManager, QuantizeRealize,
};
use tvmq::graph::{
    build_conv_net, build_resnet_ir, build_resnet_ir_in, calibrate_ir, evaluate, Graph,
    Layout, NetSpec, Op, TensorTy,
};
use tvmq::runtime::TensorData;
use tvmq::util::rng::Rng64;

fn random_net(rng: &mut Rng64) -> NetSpec {
    let stages = (1..=rng.range_usize(1, 3))
        .map(|i| tvmq::graph::builder::StageSpec {
            channels: [4usize, 8, 16][rng.range_usize(0, 2)],
            kernel: [1usize, 3][rng.range_usize(0, 1)],
            stride: rng.range_usize(1, 2),
            residual: rng.bool() && i > 1,
        })
        .collect();
    NetSpec {
        batch: rng.range_usize(1, 2),
        image: rng.range_usize(6, 12),
        in_channels: rng.range_usize(1, 4),
        stages,
        classes: rng.range_usize(2, 10),
        seed: rng.next_u64(),
    }
}

/// Bit-for-bit: dtype, shape, and raw bytes must all agree.
fn assert_matches_oracle(g: &Graph, x: &TensorData, exec: &ArenaExec, tag: &str) {
    let want = evaluate(g, x).unwrap();
    let got = exec.run(x).unwrap();
    assert_eq!(want, got, "{tag}: arena output diverged from the interpreter");
}

#[test]
fn prop_arena_matches_interp_fp32_random_nets() {
    let mut rng = Rng64::seed_from_u64(2025);
    for case in 0..12 {
        let spec = random_net(&mut rng);
        let g = build_conv_net(&spec).unwrap();
        let x = calibrate_ir(&g, rng.next_u64());
        for threads in [1usize, 2, 4] {
            let exec = ArenaExec::with_options(&g, true, threads).unwrap();
            assert!(
                exec.compiled().fused_chains > 0,
                "case {case}: fp32 conv+bias+relu chains must fuse"
            );
            assert_matches_oracle(&g, &x, &exec, &format!("fp32 case {case} t{threads}"));
        }
    }
}

#[test]
fn fp32_chains_compile_to_single_fused_steps() {
    // NetSpec::small: three conv+bias+relu stages (the middle one with a
    // residual skip) + gap + dense.  With generalized fusion each stage
    // collapses into ONE epilogue step: load, 3 fused convs, gap, fc.
    use tvmq::graph::compile::Slot;
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let exec = ArenaExec::compile(&g).unwrap();
    let cg = exec.compiled();
    assert_eq!(cg.fused_chains, 3, "three fp32 conv chains should fuse");
    assert_eq!(
        cg.steps.len(),
        6,
        "expected load + 3 fused convs + gap + fc, got: {:?}",
        cg.steps.iter().map(|s| s.name.clone()).collect::<Vec<_>>()
    );

    // The residual stage became a two-input epilogue step whose third
    // source (the skip value) must stay live through the step — i.e. the
    // planner may not alias it with the destination (regression for the
    // two-input lifetime extension).
    let res_steps: Vec<_> = cg.steps.iter().filter(|s| s.op.has_residual()).collect();
    assert_eq!(res_steps.len(), 1, "exactly one residual stage in NetSpec::small");
    let step = res_steps[0];
    assert_eq!(step.srcs.len(), 3, "residual epilogue carries a third operand");
    let (Slot::Arena { offset: ro, bytes: rb }, _) = &step.srcs[2] else {
        panic!("residual operand should live in the arena");
    };
    let Slot::Arena { offset: d, bytes: db } = step.dst else {
        panic!("destination should live in the arena");
    };
    assert!(
        ro + rb <= d || d + db <= *ro,
        "residual operand [{ro}+{rb}] aliases the fused step's dst [{d}+{db}]"
    );

    // And the fused program still matches the oracle bit-for-bit.
    let x = calibrate_ir(&g, 21);
    assert_matches_oracle(&g, &x, &exec, "fp32 fused-shape");
}

#[test]
fn prop_arena_matches_interp_quantized_random_nets() {
    let mut rng = Rng64::seed_from_u64(777);
    for case in 0..10 {
        let spec = random_net(&mut rng);
        let g = build_conv_net(&spec).unwrap();
        let calib = calibrate_ir(&g, rng.next_u64());
        let scales = calibrate_graph(&g, &calib).unwrap();
        let qg = QuantizeRealize { scales }.run(&g).unwrap();
        let x = calibrate_ir(&qg, rng.next_u64());
        for (fuse, threads) in [(true, 1), (true, 3), (false, 1)] {
            let exec = ArenaExec::with_options(&qg, fuse, threads).unwrap();
            assert_matches_oracle(
                &qg, &x, &exec,
                &format!("int8 case {case} fuse={fuse} t{threads}"),
            );
            if fuse {
                assert!(
                    exec.compiled().fused_chains > 0,
                    "case {case}: realized graph must fuse at least one q/dq chain"
                );
            }
        }
    }
}

#[test]
fn arena_matches_interp_on_packed_layouts() {
    let g = build_resnet_ir(1, 16, 7).unwrap();
    let x = calibrate_ir(&g, 4);
    for cb in [4usize, 16] {
        let pm = PassManager::new()
            .add(AlterConvLayout { c_block: cb, k_block: cb })
            .add(CancelLayoutTransforms)
            .add(ConstantFold);
        let packed = pm.run(&g).unwrap();
        for threads in [1usize, 2] {
            let exec = ArenaExec::with_options(&packed, true, threads).unwrap();
            assert_matches_oracle(&packed, &x, &exec, &format!("nchw{cb}c t{threads}"));
        }
    }
}

#[test]
fn arena_matches_interp_on_nhwc_graph() {
    let mut g = Graph::new();
    let mut rng = Rng64::seed_from_u64(55);
    let x = g.add_input("x", TensorTy::f32(vec![1, 8, 8, 4]));
    let w: Vec<f32> = (0..3 * 3 * 4 * 8).map(|_| rng.normal() * 0.2).collect();
    let wid = g.add_const_f32("w", vec![3, 3, 4, 8], w).unwrap();
    let conv = g
        .add("conv", Op::Conv2d { stride: 1, padding: 1, layout: Layout::Nhwc }, vec![x, wid])
        .unwrap();
    let b: Vec<f32> = (0..8).map(|_| rng.normal() * 0.1).collect();
    let bid = g.add_const_f32("b", vec![8], b).unwrap();
    let biased = g
        .add("bias", Op::BiasAdd { layout: Layout::Nhwc }, vec![conv, bid])
        .unwrap();
    let act = g.add("relu", Op::Relu, vec![biased]).unwrap();
    let pooled = g
        .add(
            "pool",
            Op::MaxPool { window: 2, stride: 2, padding: 0, layout: Layout::Nhwc },
            vec![act],
        )
        .unwrap();
    let gap = g
        .add("gap", Op::GlobalAvgPool { layout: Layout::Nhwc }, vec![pooled])
        .unwrap();
    let fw: Vec<f32> = (0..8 * 10).map(|_| rng.normal() * 0.3).collect();
    let fwid = g.add_const_f32("fc.w", vec![8, 10], fw).unwrap();
    g.output = g.add("fc", Op::Dense, vec![gap, fwid]).unwrap();
    g.validate().unwrap();

    let xin = calibrate_ir(&g, 9);
    for threads in [1usize, 2] {
        let exec = ArenaExec::with_options(&g, true, threads).unwrap();
        assert_matches_oracle(&g, &xin, &exec, &format!("nhwc t{threads}"));
    }
}

#[test]
fn arena_matches_interp_on_packed_io_graph() {
    // Input and every op natively in NCHW{4}c: exercises the packed
    // bias/pool/gap kernels that AlterOpLayout graphs don't reach.
    let mut g = Graph::new();
    let mut rng = Rng64::seed_from_u64(91);
    let x = g.add_input("x", TensorTy::f32(vec![1, 2, 4, 4, 4]));
    let w: Vec<f32> = (0..8 * 8 * 9).map(|_| rng.normal() * 0.2).collect();
    let wid = g
        .add_const_f32("w", vec![2, 2, 3, 3, 4, 4], w)
        .unwrap();
    let conv = g
        .add(
            "conv",
            Op::Conv2d { stride: 1, padding: 1, layout: Layout::Nchwc(4) },
            vec![x, wid],
        )
        .unwrap();
    let b: Vec<f32> = (0..8).map(|_| rng.normal() * 0.1).collect();
    let bid = g.add_const_f32("b", vec![8], b).unwrap();
    let biased = g
        .add("bias", Op::BiasAdd { layout: Layout::Nchwc(4) }, vec![conv, bid])
        .unwrap();
    let act = g.add("relu", Op::Relu, vec![biased]).unwrap();
    let pooled = g
        .add(
            "pool",
            Op::MaxPool { window: 2, stride: 2, padding: 0, layout: Layout::Nchwc(4) },
            vec![act],
        )
        .unwrap();
    g.output = g
        .add("gap", Op::GlobalAvgPool { layout: Layout::Nchwc(4) }, vec![pooled])
        .unwrap();
    g.validate().unwrap();

    let xin = calibrate_ir(&g, 13);
    let exec = ArenaExec::compile(&g).unwrap();
    assert_matches_oracle(&g, &xin, &exec, "nchwc-native");
}

#[test]
fn arena_matches_interp_int8_all_layouts() {
    // The tentpole differential: natively built NHWC and NCHW{c} models,
    // quantize-realized, must pin the fused packed int8 chains
    // (q → packed conv → dq → bias → relu, residual adds included)
    // bit-for-bit to the oracle at several fan-outs — and the unfused
    // ablation (standalone int8 packed convs, materialized q/dq
    // boundaries) must agree too.
    for layout in [Layout::Nchw, Layout::Nhwc, Layout::Nchwc(4)] {
        let g = build_resnet_ir_in(1, 12, 11, layout).unwrap();
        let calib = calibrate_ir(&g, 5);
        let scales = calibrate_graph(&g, &calib).unwrap();
        let qg = QuantizeRealize { scales }.run(&g).unwrap();
        let x = calibrate_ir(&qg, 6);
        for (fuse, threads) in [(true, 1), (true, 4), (false, 1)] {
            let exec = ArenaExec::with_options(&qg, fuse, threads).unwrap();
            if fuse {
                assert!(
                    exec.compiled().steps.iter().any(|s| {
                        s.op.conv_layout() == Some(layout)
                            && s.op.epilogue().map_or(false, |e| !e.is_identity())
                    }),
                    "{layout:?}: expected fused int8 epilogue steps in the model's layout"
                );
            }
            assert_matches_oracle(
                &qg, &x, &exec,
                &format!("int8 {layout:?} fuse={fuse} t{threads}"),
            );
        }
    }
}

#[test]
fn forced_microkernel_tile_boundaries_match_oracle() {
    // Tile-boundary differentials for the register-blocked int8
    // microkernels: geometries chosen against the model's dims so every
    // tail case runs — ow = 12 with mr ∈ {5, 3} leaves m-tails, the
    // 10-class dense / conv channel counts with nr ∈ {3, 16} leave
    // n-tails (or clamp whole), and reduction spans c·r·s with
    // ku ∈ {7, 16, 64} leave k-tails in the chunked scalar fallback.
    // Integer accumulation is order-independent, so every geometry must
    // be bit-for-bit the interpreter's answer in all three layouts.
    use tvmq::graph::compile::{ScheduleOverrides, StepSched};
    use tvmq::graph::MicroKernel;

    let tiles = [
        MicroKernel { mr: 5, nr: 3, ku: 7 },
        MicroKernel { mr: 8, nr: 16, ku: 16 },
        MicroKernel { mr: 3, nr: 5, ku: 64 },
    ];
    for layout in [Layout::Nchw, Layout::Nhwc, Layout::Nchwc(4)] {
        let g = build_resnet_ir_in(1, 12, 11, layout).unwrap();
        let calib = calibrate_ir(&g, 5);
        let scales = calibrate_graph(&g, &calib).unwrap();
        let qg = QuantizeRealize { scales }.run(&g).unwrap();
        let x = calibrate_ir(&qg, 6);
        let want = evaluate(&qg, &x).unwrap();
        for mk in tiles {
            let ovr = ScheduleOverrides {
                default_sched: StepSched { banding: None, max_bands: 0, micro: Some(mk) },
                ..ScheduleOverrides::default()
            };
            for threads in [1usize, 2] {
                let exec = ArenaExec::with_schedule(&qg, true, threads, &ovr).unwrap();
                assert!(
                    exec.compiled().steps.iter().any(|s| s.packed.is_some()),
                    "{layout:?} {mk:?}: no step took the pre-packed microkernel path"
                );
                let got = exec.run(&x).unwrap();
                assert_eq!(
                    want, got,
                    "{layout:?} {mk:?} t{threads}: tile boundary diverged from the oracle"
                );
            }
        }
    }
}

#[test]
fn arena_matches_interp_fp32_packed_epilogues() {
    // fp32 epilogue fusion on the packed layouts (bias+relu+residual
    // folded into NHWC / NCHW{c} conv steps) — previously these lowered
    // their tails 1:1.
    for layout in [Layout::Nhwc, Layout::Nchwc(8)] {
        let g = build_resnet_ir_in(1, 12, 13, layout).unwrap();
        let x = calibrate_ir(&g, 3);
        for threads in [1usize, 3] {
            let exec = ArenaExec::with_options(&g, true, threads).unwrap();
            assert!(
                exec.compiled().steps.iter().any(|s| {
                    s.op.conv_layout() == Some(layout) && s.op.has_residual()
                }),
                "{layout:?}: expected a fused packed residual epilogue"
            );
            assert_matches_oracle(&g, &x, &exec, &format!("fp32 {layout:?} t{threads}"));
        }
    }
}

#[test]
fn arena_resnet_quantized_fused_bit_exact_and_counted() {
    let g = build_resnet_ir(2, 16, 3).unwrap();
    let calib = calibrate_ir(&g, 1);
    let scales = calibrate_graph(&g, &calib).unwrap();
    let qg = QuantizeRealize { scales }.run(&g).unwrap();
    let x = calibrate_ir(&qg, 2);

    let exec = ArenaExec::with_options(&qg, true, 3).unwrap();
    assert_matches_oracle(&qg, &x, &exec, "resnet int8 fused");
    assert!(exec.compiled().fused_chains >= 9, "all realized convs should fuse");

    let c = exec.counters();
    assert_eq!(c.invocations, 1);
    assert_eq!(c.dispatches, 1, "arena serves an inference as one dispatch");
    assert_eq!(c.dynamic_allocs, 0, "static plan means no dynamic allocation");
    assert!(c.instructions > 0);
}

#[test]
fn arena_plan_invariants_hold() {
    // No placement overlap among simultaneously-live values, and the
    // planned arena never exceeds the unshared (no-reuse) total.
    let g = build_resnet_ir(1, 16, 5).unwrap();
    let calib = calibrate_ir(&g, 1);
    let scales = calibrate_graph(&g, &calib).unwrap();
    let qg = QuantizeRealize { scales }.run(&g).unwrap();

    for (tag, graph, fuse) in
        [("fp32", &g, true), ("int8-fused", &qg, true), ("int8-unfused", &qg, false)]
    {
        let exec = ArenaExec::with_options(graph, fuse, 1).unwrap();
        let cg = exec.compiled();
        cg.plan.verify().unwrap_or_else(|e| panic!("{tag}: overlapping plan: {e}"));
        assert!(cg.arena_bytes > 0, "{tag}: empty arena");
        assert!(
            cg.arena_bytes <= cg.unshared_bytes(),
            "{tag}: arena {} exceeds unshared {}",
            cg.arena_bytes,
            cg.unshared_bytes()
        );
        assert!(
            cg.plan.reuse_factor() >= 1.0,
            "{tag}: reuse factor below 1"
        );
    }

    // Fusion must shrink the instruction stream.
    let fused = ArenaExec::with_options(&qg, true, 1).unwrap();
    let unfused = ArenaExec::with_options(&qg, false, 1).unwrap();
    assert!(
        fused.compiled().steps.len() < unfused.compiled().steps.len(),
        "fusion did not reduce step count"
    );
}

#[test]
fn arena_rejects_wrong_shapes() {
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let exec = ArenaExec::compile(&g).unwrap();
    let bad = TensorData::zeros(tvmq::runtime::DType::F32, vec![1, 3, 4, 4]);
    assert!(exec.run(&bad).is_err());

    let x = calibrate_ir(&g, 3);
    let mut bad_out = TensorData::zeros(tvmq::runtime::DType::F32, vec![1, 3]);
    assert!(exec.run_into(&x, &mut bad_out).is_err());
}

#[test]
fn arena_run_into_matches_run() {
    let g = build_conv_net(&NetSpec::small(2)).unwrap();
    let exec = ArenaExec::compile(&g).unwrap();
    let x = calibrate_ir(&g, 8);
    let via_run = exec.run(&x).unwrap();
    let mut out = TensorData::zeros(
        via_run.dtype,
        via_run.shape.clone(),
    );
    exec.run_into(&x, &mut out).unwrap();
    assert_eq!(via_run, out);
}
