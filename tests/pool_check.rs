//! Exhaustive interleaving verification of the arena pool's epoch
//! protocol — the concurrency gate ROADMAP item 5 asked for.
//!
//! Each test hands `tvmq::check::check_pool` a small worker/band/epoch
//! configuration; the checker runs the **real** `dispatch`/`worker_loop`/
//! `signal_shutdown` code under a deterministic scheduler and explores
//! every schedule within the stated preemption bound (see
//! `tvmq::check` module docs for exactly what that does and does not
//! prove).  A reported `complete` means the property held over the whole
//! bounded schedule tree, not a sample.
//!
//! Environment knobs (CI sets all three):
//! - `TVMQ_CHECK_BUDGET` — max schedules per scenario (default 200000);
//!   a truncated scenario FAILS its test, because partial coverage is
//!   not proof.
//! - `TVMQ_CHECK_PREEMPTIONS` — preemption bound for the large (3×3)
//!   scenario (default 1; the small scenarios always run at 2).
//! - `TVMQ_CHECK_SUMMARY` — JSONL path appended with one line per
//!   scenario (explored-schedule counts; uploaded as a CI artifact).

use tvmq::check::{check_pool, check_pool_with, Explorer, PoolCheckConfig, Report, SabotageBug};

fn budget() -> usize {
    std::env::var("TVMQ_CHECK_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000)
}

fn big_config_preemptions() -> usize {
    std::env::var("TVMQ_CHECK_PREEMPTIONS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

fn explorer(preemptions: usize) -> Explorer {
    Explorer { max_schedules: budget(), max_decisions: 10_000, preemptions }
}

/// Append one JSONL record of what a scenario explored (CI artifact).
fn record_summary(scenario: &str, cfg: &PoolCheckConfig, preemptions: usize, r: &Report) {
    let Some(path) = std::env::var_os("TVMQ_CHECK_SUMMARY") else {
        return;
    };
    use std::io::Write;
    let line = format!(
        "{{\"scenario\":\"{scenario}\",\"workers\":{},\"bands\":{},\"epochs\":{},\
         \"preemptions\":{preemptions},\"schedules\":{},\"complete\":{},\
         \"peak_decisions\":{}}}\n",
        cfg.workers, cfg.bands, cfg.epochs, r.schedules, r.complete, r.peak_decisions
    );
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = f.write_all(line.as_bytes());
    }
}

/// Check `cfg` exhaustively at `preemptions`; fail on any convicted
/// schedule AND on budget truncation (incomplete exploration is not a
/// pass).
fn prove(scenario: &str, cfg: PoolCheckConfig, preemptions: usize) -> Report {
    let r = check_pool(cfg, explorer(preemptions))
        .unwrap_or_else(|f| panic!("{scenario}: {f}"));
    record_summary(scenario, &cfg, preemptions, &r);
    assert!(
        r.complete,
        "{scenario}: exploration truncated at {} schedules — raise TVMQ_CHECK_BUDGET",
        r.schedules
    );
    r
}

fn cfg(workers: usize, bands: usize, epochs: usize) -> PoolCheckConfig {
    PoolCheckConfig { workers, bands, epochs, panic_band: None }
}

/// Covering-exactly-once + termination over every schedule, small
/// configurations, preemption bound 2.
#[test]
fn small_configs_prove_covering_and_termination_at_preemption_2() {
    for (w, b) in [(1, 1), (1, 2), (2, 2), (2, 3)] {
        let name = format!("cover-{w}w{b}b");
        let r = prove(&name, cfg(w, b, 2), 2);
        assert!(r.schedules >= 2, "{name}: {} schedules — scheduler never branched", r.schedules);
    }
}

/// The acceptance-criteria configuration: 3 workers × 3 bands, two
/// back-to-back epochs plus shutdown, exhaustive at the stated
/// preemption bound.
#[test]
fn three_workers_three_bands_is_exhaustively_verified() {
    // Preemption bound 0 first: every blocking-driven ordering, both
    // epochs — cheap and still a complete tree.
    let r0 = prove("cover-3w3b-p0", cfg(3, 3, 2), 0);
    assert!(r0.schedules >= 6, "3 workers must yield at least 3! ack orders, got {}", r0.schedules);
    // Then the stated bound (default 1) over a single epoch + shutdown.
    prove("cover-3w3b", cfg(3, 3, 1), big_config_preemptions());
}

/// Unwind soundness: a panicking worker band still acknowledges its
/// epoch, the panic re-raises on the dispatcher exactly once, and the
/// next epoch runs clean — under every schedule.
#[test]
fn panicking_worker_band_is_unwind_sound_under_every_schedule() {
    prove(
        "unwind-worker-band",
        PoolCheckConfig { workers: 2, bands: 3, epochs: 2, panic_band: Some(1) },
        1,
    );
}

/// Unwind soundness when the *dispatcher's own* band panics: the epoch
/// barrier must still wait out the workers during unwind, and the next
/// dispatch starts clean.
#[test]
fn panicking_dispatcher_band_is_unwind_sound_under_every_schedule() {
    prove(
        "unwind-band0",
        PoolCheckConfig { workers: 2, bands: 2, epochs: 2, panic_band: Some(0) },
        1,
    );
}

/// The checker's own oracle: a deliberately lost "work" wakeup (workers
/// asleep through a dispatch) must be convicted as a deadlock.  A green
/// checker that cannot find this bug proves nothing.
#[test]
fn checker_convicts_a_lost_work_wakeup() {
    let f = check_pool_with(cfg(2, 2, 1), explorer(1), Some(SabotageBug::DropFirstWorkWake))
        .expect_err("a dropped work wakeup must be detected");
    assert!(
        f.description.contains("deadlock"),
        "expected a deadlock conviction, got: {f}"
    );
    assert!(!f.schedule.is_empty(), "conviction must carry the failing schedule");
}

/// Same oracle for the other direction: a lost "done" wakeup (dispatcher
/// asleep through the final acknowledgement) must be convicted.
#[test]
fn checker_convicts_a_lost_done_wakeup() {
    let f = check_pool_with(cfg(2, 2, 1), explorer(1), Some(SabotageBug::DropDoneWake))
        .expect_err("a dropped done wakeup must be detected");
    assert!(
        f.description.contains("deadlock"),
        "expected a deadlock conviction, got: {f}"
    );
}
