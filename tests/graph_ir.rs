//! Graph IR + passes: construction, validation, interpreter semantics, and
//! the semantic-preservation property every pass must satisfy.
//!
//! Property-style tests use the in-tree seeded PRNG (the offline build has
//! no proptest): each runs dozens of randomized cases deterministically.

use tvmq::graph::passes::quantize_graph_with_report as _qg;
use tvmq::graph::passes::{
    calibrate_graph, AlterConvLayout, CancelLayoutTransforms, ConstantFold, DeadCodeElim,
    FusionPass, Pass, PassManager,
};
use tvmq::graph::{
    build_conv_net, build_resnet_ir, calibrate_ir, evaluate, Graph, Layout, NetSpec, Op,
    TensorTy,
};
use tvmq::runtime::TensorData;
use tvmq::util::rng::Rng64;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).fold(0f32, |m, (x, y)| m.max((x - y).abs()))
}

fn random_net(rng: &mut Rng64) -> NetSpec {
    let stages = (1..=rng.range_usize(1, 3))
        .map(|i| tvmq::graph::builder::StageSpec {
            channels: [4usize, 8, 16][rng.range_usize(0, 2)],
            kernel: [1usize, 3][rng.range_usize(0, 1)],
            stride: rng.range_usize(1, 2),
            residual: rng.bool() && i > 1,
        })
        .collect();
    NetSpec {
        batch: rng.range_usize(1, 2),
        image: rng.range_usize(6, 12),
        in_channels: rng.range_usize(1, 4),
        stages,
        classes: rng.range_usize(2, 10),
        seed: rng.next_u64(),
    }
}

#[test]
fn build_and_validate_small_net() {
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    g.validate().unwrap();
    assert!(g.len() > 10);
    assert!(g.const_bytes() > 0);
}

#[test]
fn interp_produces_finite_logits() {
    let g = build_resnet_ir(2, 16, 3).unwrap();
    let x = calibrate_ir(&g, 1);
    let out = evaluate(&g, &x).unwrap();
    assert_eq!(out.shape, vec![2, 10]);
    assert!(out.as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn validation_rejects_type_mismatch() {
    let mut g = Graph::new();
    let x = g.add_input("x", TensorTy::f32(vec![1, 4, 8, 8]));
    let w = g
        .add_const_f32("w", vec![8, 5, 3, 3], vec![0.0; 8 * 5 * 3 * 3])
        .unwrap();
    // C mismatch: 4 vs 5.
    assert!(g
        .add("conv", Op::Conv2d { stride: 1, padding: 1, layout: Layout::Nchw }, vec![x, w])
        .is_err());
}

#[test]
fn validation_rejects_forward_reference() {
    let mut g = Graph::new();
    let x = g.add_input("x", TensorTy::f32(vec![1, 2]));
    assert!(g.add("bad", Op::Relu, vec![x + 5]).is_err());
}

#[test]
fn dce_removes_dead_nodes_and_preserves_semantics() {
    let mut g = build_conv_net(&NetSpec::small(1)).unwrap();
    let keep_out = g.output;
    // Add a dead branch.
    let dead = g.add("dead.relu", Op::Relu, vec![g.input]).unwrap();
    let _ = g.add("dead.relu2", Op::Relu, vec![dead]).unwrap();
    g.output = keep_out;
    let before = g.len();
    let x = calibrate_ir(&g, 2);
    let want = evaluate(&g, &x).unwrap();
    let g2 = DeadCodeElim.run(&g).unwrap();
    g2.validate().unwrap();
    assert!(g2.len() < before);
    let got = evaluate(&g2, &x).unwrap();
    assert_eq!(want, got);
}

#[test]
fn constant_fold_preserves_semantics() {
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let x = calibrate_ir(&g, 3);
    let want = evaluate(&g, &x).unwrap();
    let g2 = ConstantFold.run(&g).unwrap();
    g2.validate().unwrap();
    let got = evaluate(&g2, &x).unwrap();
    assert_eq!(want, got);
}

#[test]
fn fusion_plan_valid_and_smaller_than_per_op() {
    let g = build_resnet_ir(1, 16, 5).unwrap();
    let fused = FusionPass { enabled: true }.plan(&g).unwrap();
    let unfused = FusionPass { enabled: false }.plan(&g).unwrap();
    fused.validate(&g).unwrap();
    unfused.validate(&g).unwrap();
    assert!(fused.group_count() < unfused.group_count());
    // Every anchor op heads at most one group with its elementwise tail.
    let compute_nodes = g
        .nodes
        .iter()
        .filter(|n| !matches!(n.op, Op::Input | Op::Constant(_)))
        .count();
    assert_eq!(
        unfused.group_count(),
        compute_nodes,
        "per-op mode must have one group per compute node"
    );
}

#[test]
fn prop_fusion_plan_valid_on_random_graphs() {
    let mut rng = Rng64::seed_from_u64(99);
    for _ in 0..25 {
        let spec = random_net(&mut rng);
        let g = build_conv_net(&spec).unwrap();
        for enabled in [true, false] {
            let plan = FusionPass { enabled }.plan(&g).unwrap();
            plan.validate(&g).unwrap();
        }
    }
}

#[test]
fn alter_layout_preserves_semantics_when_divisible() {
    let g = build_resnet_ir(1, 16, 7).unwrap();
    let x = calibrate_ir(&g, 4);
    let want = evaluate(&g, &x).unwrap().as_f32().unwrap();
    for cb in [4usize, 8, 16] {
        let pm = PassManager::new()
            .add(AlterConvLayout { c_block: cb, k_block: cb })
            .add(CancelLayoutTransforms)
            .add(ConstantFold);
        let g2 = pm.run(&g).unwrap();
        g2.validate().unwrap();
        let got = evaluate(&g2, &x).unwrap().as_f32().unwrap();
        let err = max_abs_diff(&want, &got);
        assert!(err < 1e-3, "cb={cb}: packed conv diverged by {err}");
    }
}

#[test]
fn alter_layout_packs_eligible_convs() {
    let g = build_resnet_ir(1, 16, 7).unwrap();
    let g2 = AlterConvLayout { c_block: 16, k_block: 16 }.run(&g).unwrap();
    let packed = g2
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::Conv2d { layout: Layout::Nchwc(_), .. }))
        .count();
    // All convs except the 3-channel stem pack at cb=16.
    assert!(packed >= 8, "expected most convs packed, got {packed}");
    // Semantics preserved through the cancellation pass (resnet has
    // elementwise ops between convs, so no adjacent pairs cancel here;
    // direct-chain cancellation is covered below).
    let g3 = CancelLayoutTransforms.run(&g2).unwrap();
    let x = calibrate_ir(&g, 5);
    let a = evaluate(&g, &x).unwrap().as_f32().unwrap();
    let b = evaluate(&g3, &x).unwrap().as_f32().unwrap();
    assert!(max_abs_diff(&a, &b) < 1e-3);
}

#[test]
fn cancel_layout_transforms_on_direct_conv_chain() {
    // conv -> conv with no elementwise in between: the unpack/pack pair at
    // the boundary must cancel so the interior stays packed.
    let mut g = Graph::new();
    let mut rng = Rng64::seed_from_u64(41);
    let x = g.add_input("x", TensorTy::f32(vec![1, 8, 8, 8]));
    let mut rand_w = |k: usize, c: usize| -> Vec<f32> {
        (0..k * c * 9).map(|_| rng.normal() * 0.2).collect()
    };
    let w1 = g.add_const_f32("w1", vec![8, 8, 3, 3], rand_w(8, 8)).unwrap();
    let c1 = g
        .add("c1", Op::Conv2d { stride: 1, padding: 1, layout: Layout::Nchw }, vec![x, w1])
        .unwrap();
    let w2 = g.add_const_f32("w2", vec![8, 8, 3, 3], rand_w(8, 8)).unwrap();
    let c2 = g
        .add("c2", Op::Conv2d { stride: 1, padding: 1, layout: Layout::Nchw }, vec![c1, w2])
        .unwrap();
    g.output = c2;
    g.validate().unwrap();

    let packed = AlterConvLayout { c_block: 4, k_block: 4 }.run(&g).unwrap();
    let count = |gr: &Graph| {
        gr.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::LayoutTransform { .. }))
            .count()
    };
    let before = count(&packed);
    assert_eq!(before, 4, "pack/unpack around each of two convs");
    let cancelled = CancelLayoutTransforms.run(&packed).unwrap();
    assert_eq!(count(&cancelled), 2, "interior unpack+pack pair must cancel");

    let xin = calibrate_ir(&g, 6);
    let want = evaluate(&g, &xin).unwrap().as_f32().unwrap();
    let got = evaluate(&cancelled, &xin).unwrap().as_f32().unwrap();
    assert!(max_abs_diff(&want, &got) < 1e-3);
}

#[test]
fn quantize_realize_high_sqnr() {
    let g = build_resnet_ir(1, 16, 11).unwrap();
    let calib = calibrate_ir(&g, 6);
    let eval = calibrate_ir(&g, 7);
    let (qg, sqnr) = _qg(&g, &calib, &eval).unwrap();
    qg.validate().unwrap();
    assert!(sqnr > 20.0, "int8 IR sqnr too low: {sqnr} dB");
    // The realized graph must contain the qnn boundary operators.
    let quants = qg
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::Quantize { .. }))
        .count();
    let deqs = qg
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::Dequantize { .. }))
        .count();
    assert!(quants >= 9 && deqs >= 9, "q={quants} dq={deqs}");
}

#[test]
fn calibrate_graph_covers_all_anchors() {
    let g = build_resnet_ir(1, 16, 13).unwrap();
    let scales = calibrate_graph(&g, &calibrate_ir(&g, 8)).unwrap();
    let anchors = g.nodes.iter().filter(|n| n.op.is_anchor()).count();
    assert_eq!(scales.len(), anchors);
    assert!(scales.values().all(|s| *s > 0.0));
}

#[test]
fn prop_pass_pipeline_random_nets() {
    let mut rng = Rng64::seed_from_u64(2024);
    for _ in 0..10 {
        let spec = random_net(&mut rng);
        let g = build_conv_net(&spec).unwrap();
        let x = calibrate_ir(&g, rng.next_u64());
        let want = evaluate(&g, &x).unwrap().as_f32().unwrap();
        let pm = PassManager::new()
            .add(ConstantFold)
            .add(DeadCodeElim)
            .add(AlterConvLayout { c_block: 4, k_block: 4 })
            .add(CancelLayoutTransforms)
            .add(ConstantFold);
        let g2 = pm.run(&g).unwrap();
        let got = evaluate(&g2, &x).unwrap().as_f32().unwrap();
        assert!(
            max_abs_diff(&want, &got) < 1e-3,
            "pipeline diverged on {spec:?}"
        );
    }
}

#[test]
fn interp_rejects_wrong_input_shape() {
    let g = build_conv_net(&NetSpec::small(1)).unwrap();
    let bad = TensorData::zeros(tvmq::runtime::DType::F32, vec![1, 3, 4, 4]);
    assert!(evaluate(&g, &bad).is_err());
}
